//! Synthetic dataset substrate.
//!
//! The environment has no CIFAR10/ImageNet (DESIGN.md §3), so the data
//! pipeline synthesizes deterministic, non-trivially-learnable image and
//! vector classification tasks:
//!
//! * [`SynthCifar`] — class-conditional images built from per-class
//!   mixtures of oriented gratings and colored blobs, with per-sample
//!   geometric jitter and noise.  Plays CIFAR10's role; a "hard" preset
//!   (more classes, more noise, weaker class signal) plays ImageNet's
//!   role in the Table VI analogue.
//! * [`Blobs`] — Gaussian clusters in R^d (MLP workloads).
//! * [`Spirals`] — interleaved 2D spirals lifted into R^d — a task
//!   linear models fail at, so accuracy actually reflects capacity.
//!
//! Every sample is generated on demand from (seed, split, index), so the
//! pipeline has no storage, is exactly reproducible, and shuffling is a
//! permutation of indices.  [`Loader`] assembles batches as HostTensors
//! with optional train-time augmentation (flip/shift).

mod loader;

pub use loader::{Batch, Loader, Split};

use crate::util::rng::Rng;

/// A classification dataset generating samples on demand.
pub trait Dataset: Send + Sync {
    /// Shape of one sample (e.g. [16, 16, 3] or [32]).
    fn input_shape(&self) -> Vec<usize>;
    fn num_classes(&self) -> usize;
    fn len(&self, split: Split) -> usize;
    fn is_empty(&self, split: Split) -> bool {
        self.len(split) == 0
    }
    /// Write sample `index` of `split` into `out` (len = prod(shape)),
    /// returning its label.
    fn sample(&self, split: Split, index: usize, out: &mut [f32]) -> usize;
    fn name(&self) -> &str;
}

// ---------------------------------------------------------------------------
// SynthCifar
// ---------------------------------------------------------------------------

/// Per-class generative template: K oriented gratings + a colored blob.
#[derive(Debug, Clone)]
struct ClassTemplate {
    /// (amplitude, fx, fy, phase, channel weights)
    gratings: Vec<(f32, f32, f32, f32, [f32; 3])>,
    blob_center: (f32, f32),
    blob_radius: f32,
    blob_color: [f32; 3],
}

/// Class-conditional synthetic image dataset.
#[derive(Debug, Clone)]
pub struct SynthCifar {
    pub size: usize,
    pub classes: usize,
    pub train_len: usize,
    pub test_len: usize,
    /// Std of additive Gaussian pixel noise.
    pub noise: f32,
    /// Scale of the class signal (lower = harder).
    pub signal: f32,
    seed: u64,
    templates: Vec<ClassTemplate>,
    name: String,
}

impl SynthCifar {
    pub fn new(seed: u64, size: usize, classes: usize, train_len: usize,
               test_len: usize, noise: f32, signal: f32, name: &str) -> Self {
        let templates = (0..classes)
            .map(|c| {
                let mut rng = Rng::new(seed ^ 0xC1A5_5E5E ^ (c as u64) << 17);
                let k = 3;
                let gratings = (0..k)
                    .map(|_| {
                        (
                            rng.range_f32(0.4, 1.0),
                            rng.range_f32(0.5, 3.0) * if rng.bool(0.5) { -1.0 } else { 1.0 },
                            rng.range_f32(0.5, 3.0) * if rng.bool(0.5) { -1.0 } else { 1.0 },
                            rng.range_f32(0.0, std::f32::consts::TAU),
                            [
                                rng.range_f32(-1.0, 1.0),
                                rng.range_f32(-1.0, 1.0),
                                rng.range_f32(-1.0, 1.0),
                            ],
                        )
                    })
                    .collect();
                ClassTemplate {
                    gratings,
                    blob_center: (rng.range_f32(0.2, 0.8), rng.range_f32(0.2, 0.8)),
                    blob_radius: rng.range_f32(0.15, 0.3),
                    blob_color: [
                        rng.range_f32(-1.0, 1.0),
                        rng.range_f32(-1.0, 1.0),
                        rng.range_f32(-1.0, 1.0),
                    ],
                }
            })
            .collect();
        Self {
            size,
            classes,
            train_len,
            test_len,
            noise,
            signal,
            seed,
            templates,
            name: name.to_string(),
        }
    }

    /// CIFAR10-role default: 10 classes, 16x16, learnable but not
    /// saturated (noise level calibrated so the fp32-proxy baseline
    /// lands in the high-80s/low-90s, leaving visible headroom for
    /// quantization-induced accuracy loss).
    pub fn standard(seed: u64) -> Self {
        Self::new(seed, 16, 10, 4096, 1024, 0.9, 0.8, "synthcifar")
    }

    /// ImageNet-role "hard" preset: more classes, weaker signal.
    pub fn hard(seed: u64) -> Self {
        Self::new(seed, 16, 20, 4096, 1024, 1.1, 0.6, "synthcifar-hard")
    }

    fn sample_seed(&self, split: Split, index: usize) -> u64 {
        let split_tag = match split {
            Split::Train => 0x7_EA1Du64,
            Split::Test => 0x7E_57u64,
        };
        self.seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(split_tag)
            .wrapping_add((index as u64) << 1)
    }
}

impl Dataset for SynthCifar {
    fn input_shape(&self) -> Vec<usize> {
        vec![self.size, self.size, 3]
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn len(&self, split: Split) -> usize {
        match split {
            Split::Train => self.train_len,
            Split::Test => self.test_len,
        }
    }

    fn sample(&self, split: Split, index: usize, out: &mut [f32]) -> usize {
        let mut rng = Rng::new(self.sample_seed(split, index));
        let label = rng.below_usize(self.classes);
        let t = &self.templates[label];
        let s = self.size;
        debug_assert_eq!(out.len(), s * s * 3);

        // Per-sample jitter: translation, amplitude scale, blob drift.
        let dx = rng.range_f32(-0.15, 0.15);
        let dy = rng.range_f32(-0.15, 0.15);
        let amp = self.signal * rng.range_f32(0.8, 1.2);
        let (bcx, bcy) = (
            t.blob_center.0 + rng.range_f32(-0.08, 0.08),
            t.blob_center.1 + rng.range_f32(-0.08, 0.08),
        );

        for y in 0..s {
            for x in 0..s {
                let u = x as f32 / s as f32 + dx;
                let v = y as f32 / s as f32 + dy;
                let mut px = [0.0f32; 3];
                for &(a, fx, fy, phase, cw) in &t.gratings {
                    let wave =
                        (std::f32::consts::TAU * (fx * u + fy * v) + phase).sin() * a;
                    for c in 0..3 {
                        px[c] += wave * cw[c];
                    }
                }
                let d2 = (u - bcx) * (u - bcx) + (v - bcy) * (v - bcy);
                let blob = (-d2 / (2.0 * t.blob_radius * t.blob_radius)).exp();
                for c in 0..3 {
                    px[c] += blob * t.blob_color[c];
                    let noise = rng.normal_f32(0.0, self.noise);
                    out[(y * s + x) * 3 + c] = amp * px[c] + noise;
                }
            }
        }
        label
    }

    fn name(&self) -> &str {
        &self.name
    }
}

// ---------------------------------------------------------------------------
// Blobs
// ---------------------------------------------------------------------------

/// Gaussian clusters in R^dim.
#[derive(Debug, Clone)]
pub struct Blobs {
    pub dim: usize,
    pub classes: usize,
    pub train_len: usize,
    pub test_len: usize,
    pub spread: f32,
    seed: u64,
    centers: Vec<Vec<f32>>,
}

impl Blobs {
    pub fn new(seed: u64, dim: usize, classes: usize, train_len: usize,
               test_len: usize, spread: f32) -> Self {
        let centers = (0..classes)
            .map(|c| {
                let mut rng = Rng::new(seed ^ 0xB10B ^ (c as u64) << 13);
                (0..dim).map(|_| rng.range_f32(-1.0, 1.0)).collect()
            })
            .collect();
        Self { dim, classes, train_len, test_len, spread, seed, centers }
    }

    pub fn standard(seed: u64) -> Self {
        Self::new(seed, 32, 10, 4096, 1024, 0.35)
    }
}

impl Dataset for Blobs {
    fn input_shape(&self) -> Vec<usize> {
        vec![self.dim]
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn len(&self, split: Split) -> usize {
        match split {
            Split::Train => self.train_len,
            Split::Test => self.test_len,
        }
    }

    fn sample(&self, split: Split, index: usize, out: &mut [f32]) -> usize {
        let tag = match split {
            Split::Train => 1u64,
            Split::Test => 2u64,
        };
        let mut rng = Rng::new(
            self.seed
                .wrapping_mul(0x2545F4914F6CDD1D)
                .wrapping_add(tag << 40)
                .wrapping_add(index as u64),
        );
        let label = rng.below_usize(self.classes);
        for (o, c) in out.iter_mut().zip(&self.centers[label]) {
            *o = c + rng.normal_f32(0.0, self.spread);
        }
        label
    }

    fn name(&self) -> &str {
        "blobs"
    }
}

// ---------------------------------------------------------------------------
// Spirals
// ---------------------------------------------------------------------------

/// Interleaved 2D spirals lifted into `dim` dimensions via a fixed
/// random linear map — non-linearly-separable by construction.
#[derive(Debug, Clone)]
pub struct Spirals {
    pub dim: usize,
    pub classes: usize,
    pub train_len: usize,
    pub test_len: usize,
    pub noise: f32,
    seed: u64,
    /// dim x 2 lift matrix.
    lift: Vec<f32>,
}

impl Spirals {
    pub fn new(seed: u64, dim: usize, classes: usize, train_len: usize,
               test_len: usize, noise: f32) -> Self {
        let mut rng = Rng::new(seed ^ 0x5417A15);
        let lift = (0..dim * 2).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        Self { dim, classes, train_len, test_len, noise, seed, lift }
    }

    pub fn standard(seed: u64) -> Self {
        Self::new(seed, 32, 3, 4096, 1024, 0.08)
    }
}

impl Dataset for Spirals {
    fn input_shape(&self) -> Vec<usize> {
        vec![self.dim]
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn len(&self, split: Split) -> usize {
        match split {
            Split::Train => self.train_len,
            Split::Test => self.test_len,
        }
    }

    fn sample(&self, split: Split, index: usize, out: &mut [f32]) -> usize {
        let tag = match split {
            Split::Train => 3u64,
            Split::Test => 4u64,
        };
        let mut rng = Rng::new(
            self.seed
                .wrapping_mul(0xD1342543DE82EF95)
                .wrapping_add(tag << 44)
                .wrapping_add(index as u64),
        );
        let label = rng.below_usize(self.classes);
        let t = rng.range_f32(0.25, 1.0); // radius parameter
        let theta = t * 3.0 * std::f32::consts::TAU / 2.0
            + (label as f32) * std::f32::consts::TAU / self.classes as f32;
        let p = [
            t * theta.cos() + rng.normal_f32(0.0, self.noise),
            t * theta.sin() + rng.normal_f32(0.0, self.noise),
        ];
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.lift[i * 2] * p[0] + self.lift[i * 2 + 1] * p[1];
        }
        label
    }

    fn name(&self) -> &str {
        "spirals"
    }
}

/// Build a dataset by name.
pub fn build(name: &str, seed: u64) -> anyhow::Result<Box<dyn Dataset>> {
    match name {
        "synthcifar" => Ok(Box::new(SynthCifar::standard(seed))),
        "synthcifar-hard" => Ok(Box::new(SynthCifar::hard(seed))),
        "blobs" => Ok(Box::new(Blobs::standard(seed))),
        "spirals" => Ok(Box::new(Spirals::standard(seed))),
        other => anyhow::bail!(
            "unknown dataset '{other}' (have synthcifar, synthcifar-hard, blobs, spirals)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthcifar_deterministic_and_distinct() {
        let d = SynthCifar::standard(7);
        let n = d.input_shape().iter().product::<usize>();
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        let la = d.sample(Split::Train, 5, &mut a);
        let lb = d.sample(Split::Train, 5, &mut b);
        assert_eq!(la, lb);
        assert_eq!(a, b);
        let lc = d.sample(Split::Train, 6, &mut b);
        assert!(a != b || la != lc, "different indices should differ");
    }

    #[test]
    fn splits_are_disjoint_streams() {
        let d = SynthCifar::standard(7);
        let n = d.input_shape().iter().product::<usize>();
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        d.sample(Split::Train, 0, &mut a);
        d.sample(Split::Test, 0, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn labels_cover_all_classes() {
        for ds in [
            build("synthcifar", 1).unwrap(),
            build("blobs", 1).unwrap(),
            build("spirals", 1).unwrap(),
        ] {
            let n = ds.input_shape().iter().product::<usize>();
            let mut buf = vec![0.0; n];
            let mut seen = vec![false; ds.num_classes()];
            for i in 0..256 {
                let l = ds.sample(Split::Train, i, &mut buf);
                assert!(l < ds.num_classes());
                seen[l] = true;
            }
            assert!(
                seen.iter().all(|&s| s),
                "{}: classes missing in 256 samples",
                ds.name()
            );
        }
    }

    #[test]
    fn values_are_finite_and_bounded() {
        let d = SynthCifar::standard(3);
        let n = d.input_shape().iter().product::<usize>();
        let mut buf = vec![0.0; n];
        for i in 0..32 {
            d.sample(Split::Train, i, &mut buf);
            for &v in &buf {
                assert!(v.is_finite());
                assert!(v.abs() < 20.0, "pixel {v} out of sane range");
            }
        }
    }

    #[test]
    fn hard_preset_is_harder() {
        let s = SynthCifar::standard(1);
        let h = SynthCifar::hard(1);
        assert!(h.classes > s.classes);
        assert!(h.noise > s.noise);
        assert!(h.signal < s.signal);
    }

    #[test]
    fn unknown_dataset_rejected() {
        assert!(build("mnist", 0).is_err());
    }
}
