//! Batch assembly: shuffled epochs, augmentation, HostTensor staging.

use anyhow::Result;

use super::Dataset;
use crate::tensor::HostTensor;
use crate::util::rng::Rng;

/// Dataset split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

/// One staged batch.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: HostTensor,
    pub y: HostTensor,
    /// Number of real (non-padded) samples — the tail batch of an epoch
    /// is padded by wrapping, so metrics weight by this.
    pub real: usize,
}

/// Assembles shuffled, optionally augmented batches from a [`Dataset`].
pub struct Loader<'d> {
    dataset: &'d dyn Dataset,
    split: Split,
    batch_size: usize,
    augment: bool,
    rng: Rng,
    order: Vec<usize>,
    cursor: usize,
    epoch: usize,
    /// Scratch sample buffer.
    sample_buf: Vec<f32>,
}

impl<'d> Loader<'d> {
    pub fn new(dataset: &'d dyn Dataset, split: Split, batch_size: usize,
               augment: bool, seed: u64) -> Self {
        let len = dataset.len(split);
        let n = dataset.input_shape().iter().product::<usize>();
        let mut rng = Rng::new(seed ^ 0x10ADE2);
        let mut order: Vec<usize> = (0..len).collect();
        if split == Split::Train {
            rng.shuffle(&mut order);
        }
        Self {
            dataset,
            split,
            batch_size,
            augment,
            rng,
            order,
            cursor: 0,
            epoch: 0,
            sample_buf: vec![0.0; n],
        }
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.order.len().div_ceil(self.batch_size)
    }

    /// Next batch; reshuffles and wraps at epoch boundaries.
    pub fn next_batch(&mut self) -> Result<Batch> {
        let shape = self.dataset.input_shape();
        let sample_elems: usize = shape.iter().product();
        let mut xs = vec![0.0f32; self.batch_size * sample_elems];
        let mut ys = vec![0i32; self.batch_size];
        let mut real = 0;

        for b in 0..self.batch_size {
            if self.cursor >= self.order.len() {
                self.epoch += 1;
                self.cursor = 0;
                if self.split == Split::Train {
                    let mut order = std::mem::take(&mut self.order);
                    self.rng.shuffle(&mut order);
                    self.order = order;
                }
            } else if b == 0 || self.cursor != 0 {
                real += 1;
            } else {
                // wrapped mid-batch: samples from the new epoch pad the
                // tail; still count them as real work for training but
                // eval loops should iterate exactly batches_per_epoch.
                real += 1;
            }
            let idx = self.order[self.cursor];
            self.cursor += 1;
            let label =
                self.dataset
                    .sample(self.split, idx, &mut self.sample_buf);
            let dst = &mut xs[b * sample_elems..(b + 1) * sample_elems];
            dst.copy_from_slice(&self.sample_buf);
            if self.augment && shape.len() == 3 {
                augment_image(dst, &shape, &mut self.rng);
            }
            ys[b] = label as i32;
        }

        let mut dims = vec![self.batch_size];
        dims.extend_from_slice(&shape);
        Ok(Batch {
            x: HostTensor::f32(&dims, xs)?,
            y: HostTensor::i32(&[self.batch_size], ys)?,
            real,
        })
    }
}

/// Train-time augmentation for HWC images: random horizontal flip and
/// ±2px shift (zero padded).
fn augment_image(px: &mut [f32], shape: &[usize], rng: &mut Rng) {
    let (h, w, c) = (shape[0], shape[1], shape[2]);
    if rng.bool(0.5) {
        // horizontal flip
        for y in 0..h {
            for x in 0..w / 2 {
                for ch in 0..c {
                    let a = (y * w + x) * c + ch;
                    let b = (y * w + (w - 1 - x)) * c + ch;
                    px.swap(a, b);
                }
            }
        }
    }
    let dx = rng.below(5) as isize - 2;
    let dy = rng.below(5) as isize - 2;
    if dx != 0 || dy != 0 {
        let src = px.to_vec();
        for y in 0..h as isize {
            for x in 0..w as isize {
                let sy = y - dy;
                let sx = x - dx;
                for ch in 0..c {
                    let dst_i = ((y * w as isize + x) * c as isize) as usize + ch;
                    px[dst_i] = if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                        src[((sy * w as isize + sx) * c as isize) as usize + ch]
                    } else {
                        0.0
                    };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthCifar;

    #[test]
    fn batch_shapes() {
        let d = SynthCifar::standard(1);
        let mut loader = Loader::new(&d, Split::Train, 8, false, 0);
        let b = loader.next_batch().unwrap();
        assert_eq!(b.x.dims(), &[8, 16, 16, 3]);
        assert_eq!(b.y.dims(), &[8]);
        assert_eq!(b.real, 8);
    }

    #[test]
    fn deterministic_given_seed() {
        let d = SynthCifar::standard(1);
        let mut a = Loader::new(&d, Split::Train, 4, true, 42);
        let mut b = Loader::new(&d, Split::Train, 4, true, 42);
        for _ in 0..3 {
            let ba = a.next_batch().unwrap();
            let bb = b.next_batch().unwrap();
            assert_eq!(ba.x, bb.x);
            assert_eq!(ba.y, bb.y);
        }
    }

    #[test]
    fn epoch_advances_and_reshuffles() {
        let d = SynthCifar::new(1, 8, 4, 16, 8, 0.1, 1.0, "tiny");
        let mut loader = Loader::new(&d, Split::Train, 8, false, 0);
        assert_eq!(loader.batches_per_epoch(), 2);
        let first_epoch: Vec<i32> = (0..2)
            .flat_map(|_| loader.next_batch().unwrap().y.as_i32().unwrap().to_vec())
            .collect();
        assert_eq!(loader.epoch(), 0);
        loader.next_batch().unwrap();
        assert_eq!(loader.epoch(), 1);
        let _ = first_epoch;
    }

    #[test]
    fn test_split_is_stable_order() {
        let d = SynthCifar::standard(1);
        let mut a = Loader::new(&d, Split::Test, 16, false, 0);
        let mut b = Loader::new(&d, Split::Test, 16, false, 99);
        // test split never shuffles: same batches regardless of seed
        let ba = a.next_batch().unwrap();
        let bb = b.next_batch().unwrap();
        assert_eq!(ba.y, bb.y);
        assert_eq!(ba.x, bb.x);
    }

    #[test]
    fn augmentation_changes_pixels_not_labels() {
        let d = SynthCifar::standard(1);
        let mut plain = Loader::new(&d, Split::Train, 16, false, 7);
        let mut aug = Loader::new(&d, Split::Train, 16, true, 7);
        let bp = plain.next_batch().unwrap();
        let ba = aug.next_batch().unwrap();
        assert_eq!(bp.y, ba.y);
        assert_ne!(bp.x, ba.x);
    }
}
