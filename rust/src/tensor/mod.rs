//! Minimal host-side tensor type used across the coordinator.
//!
//! This is deliberately small: the heavy math runs inside the compiled
//! XLA artifacts; the coordinator only needs to stage inputs, unpack
//! outputs, checkpoint state and run the rust quantizer mirror.

use anyhow::{bail, Result};

/// Element type of a [`HostTensor`]. Mirrors the subset of XLA primitive
/// types the exported artifacts use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        4
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::U32 => "u32",
        }
    }
}

/// Typed storage for a host tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

/// A dense row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    dims: Vec<usize>,
    data: TensorData,
}

impl HostTensor {
    // ---- constructors -----------------------------------------------------

    pub fn f32(dims: &[usize], data: Vec<f32>) -> Result<Self> {
        Self::check_len(dims, data.len())?;
        Ok(Self { dims: dims.to_vec(), data: TensorData::F32(data) })
    }

    pub fn i32(dims: &[usize], data: Vec<i32>) -> Result<Self> {
        Self::check_len(dims, data.len())?;
        Ok(Self { dims: dims.to_vec(), data: TensorData::I32(data) })
    }

    pub fn u32(dims: &[usize], data: Vec<u32>) -> Result<Self> {
        Self::check_len(dims, data.len())?;
        Ok(Self { dims: dims.to_vec(), data: TensorData::U32(data) })
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self { dims: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn scalar_u32(v: u32) -> Self {
        Self { dims: vec![], data: TensorData::U32(vec![v]) }
    }

    pub fn zeros_f32(dims: &[usize]) -> Self {
        let n = dims.iter().product();
        Self { dims: dims.to_vec(), data: TensorData::F32(vec![0.0; n]) }
    }

    pub fn full_f32(dims: &[usize], v: f32) -> Self {
        let n = dims.iter().product();
        Self { dims: dims.to_vec(), data: TensorData::F32(vec![v; n]) }
    }

    fn check_len(dims: &[usize], len: usize) -> Result<()> {
        let n: usize = dims.iter().product();
        if n != len {
            bail!("dims {:?} expect {} elements, got {}", dims, n, len);
        }
        Ok(())
    }

    // ---- accessors ---------------------------------------------------------

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
            TensorData::U32(_) => DType::U32,
        }
    }

    pub fn data(&self) -> &TensorData {
        &self.data
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got {:?}", DTypeOf(other)),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            other => bail!("expected i32 tensor, got {:?}", DTypeOf(other)),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match &self.data {
            TensorData::U32(v) => Ok(v),
            other => bail!("expected u32 tensor, got {:?}", DTypeOf(other)),
        }
    }

    /// Consume the tensor, returning its f32 storage without copying —
    /// the staging path into the integer inference engine, which wants
    /// plain slices, not tensors.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got {:?}", DTypeOf(&other)),
        }
    }

    /// Fake-quantize an f32 tensor in place as one group at bitlength
    /// `bits` (fast [`crate::quant::QuantPlan`] kernel).
    pub fn fake_quant(&mut self, bits: f32) -> Result<()> {
        crate::quant::fake_quant_slice(self.as_f32_mut()?, bits);
        Ok(())
    }

    /// Scalar extraction (rank-0 or single-element tensors).
    pub fn scalar(&self) -> Result<f32> {
        if self.element_count() != 1 {
            bail!("scalar() on tensor with {} elements", self.element_count());
        }
        match &self.data {
            TensorData::F32(v) => Ok(v[0]),
            TensorData::I32(v) => Ok(v[0] as f32),
            TensorData::U32(v) => Ok(v[0] as f32),
        }
    }

    pub fn size_bytes(&self) -> usize {
        self.element_count() * self.dtype().size_bytes()
    }
}

struct DTypeOf<'a>(&'a TensorData);

impl std::fmt::Debug for DTypeOf<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let name = match self.0 {
            TensorData::F32(_) => "f32",
            TensorData::I32(_) => "i32",
            TensorData::U32(_) => "u32",
        };
        f.write_str(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let t = HostTensor::f32(&[2, 3], vec![0.0; 6]).unwrap();
        assert_eq!(t.dims(), &[2, 3]);
        assert_eq!(t.element_count(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.size_bytes(), 24);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn dim_mismatch_rejected() {
        assert!(HostTensor::f32(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        assert_eq!(HostTensor::scalar_f32(2.5).scalar().unwrap(), 2.5);
        assert_eq!(HostTensor::scalar_u32(7).scalar().unwrap(), 7.0);
        assert!(HostTensor::zeros_f32(&[2]).scalar().is_err());
    }

    #[test]
    fn into_f32_moves_storage() {
        let t = HostTensor::f32(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(t.into_f32().unwrap(), vec![1.0, 2.0, 3.0]);
        let i = HostTensor::i32(&[1], vec![4]).unwrap();
        assert!(i.into_f32().is_err());
    }

    #[test]
    fn fake_quant_in_place() {
        let mut t = HostTensor::f32(&[4], vec![-1.0, -0.3, 0.4, 1.0]).unwrap();
        t.fake_quant(1.0).unwrap();
        assert!(t.as_f32().unwrap().iter().all(|&v| v == -1.0 || v == 1.0));
        let mut i = HostTensor::i32(&[1], vec![4]).unwrap();
        assert!(i.fake_quant(4.0).is_err());
    }

    #[test]
    fn zeros_and_full() {
        let z = HostTensor::zeros_f32(&[4]);
        assert!(z.as_f32().unwrap().iter().all(|&v| v == 0.0));
        let f = HostTensor::full_f32(&[3], 8.0);
        assert!(f.as_f32().unwrap().iter().all(|&v| v == 8.0));
    }
}
