//! Offline stand-in for the `xla` PJRT bindings crate.
//!
//! The coordinator's `runtime` module programs against a small slice of
//! the real crate's API (`PjRtClient::cpu` → `HloModuleProto::from_text_file`
//! → `compile` → `execute`).  This stub keeps the whole workspace
//! building and testable in environments without the XLA C library:
//!
//! * [`Literal`] is **fully functional** as a host staging buffer
//!   (`vec1`, `reshape`, `array_shape`, `to_vec`) — the
//!   `runtime::convert` round-trip tests run against it for real.
//! * [`HloModuleProto::from_text_file`] performs a cheap structural
//!   check (the file must start with `HloModule`), so malformed
//!   artifacts are still rejected loudly.
//! * [`PjRtLoadedExecutable::execute`] returns an error: compiled
//!   artifacts cannot run without the real backend.  Everything gated on
//!   `rust/artifacts/*.hlo.txt` skips before reaching this point.
//!
//! Swap the `xla` path dependency in `rust/Cargo.toml` for the real
//! bindings to run the AOT artifacts; no call-site changes needed.

use std::fmt;
use std::path::Path;

/// Stub error type; mirrors the real crate's opaque error.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

/// XLA primitive element types (the subset the artifacts use, plus a
/// few extras so downstream `match` arms keep a reachable wildcard).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    U32,
    F32,
    F64,
}

/// Array shape of a [`Literal`]: dims + element type.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl Storage {
    fn element_type(&self) -> ElementType {
        match self {
            Storage::F32(_) => ElementType::F32,
            Storage::I32(_) => ElementType::S32,
            Storage::U32(_) => ElementType::U32,
        }
    }

    fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::U32(v) => v.len(),
        }
    }
}

/// Element types [`Literal`] can stage. Implemented for `f32`, `i32`,
/// `u32` — the dtypes the exported artifacts use.
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn vec1_literal(v: &[Self]) -> Literal
    where
        Self: Sized;
    #[doc(hidden)]
    fn extract(lit: &Literal) -> Result<Vec<Self>>
    where
        Self: Sized;
}

macro_rules! native_type {
    ($t:ty, $variant:ident, $name:literal) => {
        impl NativeType for $t {
            fn vec1_literal(v: &[Self]) -> Literal {
                Literal {
                    dims: vec![v.len() as i64],
                    storage: Storage::$variant(v.to_vec()),
                }
            }

            fn extract(lit: &Literal) -> Result<Vec<Self>> {
                match &lit.storage {
                    Storage::$variant(v) => Ok(v.clone()),
                    other => err(format!(
                        "literal holds {:?}, not {}",
                        other.element_type(),
                        $name
                    )),
                }
            }
        }
    };
}

native_type!(f32, F32, "f32");
native_type!(i32, I32, "i32");
native_type!(u32, U32, "u32");

/// Host-side literal: a dense row-major array. Fully functional in the
/// stub (it is pure host memory).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    storage: Storage,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::vec1_literal(v)
    }

    /// Same data viewed at different dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        // Check each dim, not just the product: [-2, -3] multiplies out
        // positive but is not a valid shape.
        if dims.iter().any(|&d| d < 0) || n as usize != self.storage.len() {
            return err(format!(
                "cannot reshape {} elements to {:?}",
                self.storage.len(),
                dims
            ));
        }
        Ok(Literal { dims: dims.to_vec(), storage: self.storage.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone(), ty: self.storage.element_type() })
    }

    /// Copy the elements out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    /// Decompose a tuple literal. Stub literals are always arrays, so
    /// this only errors — tuples come from executing real artifacts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        err("stub literal is not a tuple (PJRT execution is unavailable offline)")
    }
}

/// Parsed HLO module. The stub retains the text and only validates the
/// leading `HloModule` header, which is enough to reject non-HLO input.
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading '{}': {e}", path.display())))?;
        if !text.trim_start().starts_with("HloModule") {
            return err(format!(
                "'{}' is not HLO text (missing HloModule header)",
                path.display()
            ));
        }
        Ok(Self { text })
    }
}

/// Computation handle built from a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Output buffer handle. In the stub nothing ever produces one, but the
/// type keeps `execute`'s signature identical to the real crate.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        err("PJRT execution is unavailable in the offline xla stub")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Always errors: running compiled artifacts needs the real PJRT
    /// backend. (Reached only when artifacts exist but the stub is in
    /// use — the gated tests skip long before this.)
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(
            "PJRT execution is unavailable: built against the offline xla stub — \
             point rust/Cargo.toml's `xla` dependency at the real bindings to run artifacts",
        )
    }
}

/// PJRT client handle. Construction succeeds (so artifact-directory
/// validation and HLO parsing still run); only execution is unavailable.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (no PJRT backend)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = lit.reshape(&[2, 3]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.element_type(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(lit.reshape(&[7]).is_err());
        // Negative dims whose product matches the element count are
        // still invalid shapes.
        assert!(lit.reshape(&[-2, -3]).is_err());
        assert!(r.to_vec::<i32>().is_err());
        assert!(r.to_tuple().is_err());
    }

    #[test]
    fn scalar_reshape() {
        let lit = Literal::vec1(&[42u32]).reshape(&[]).unwrap();
        assert_eq!(lit.array_shape().unwrap().dims(), &[] as &[i64]);
        assert_eq!(lit.to_vec::<u32>().unwrap(), vec![42]);
    }

    #[test]
    fn hlo_header_validated() {
        let dir = std::env::temp_dir().join("xla-stub-test");
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("good.hlo.txt");
        std::fs::write(&good, "HloModule m\nENTRY e { ROOT c = f32[] constant(0) }").unwrap();
        assert!(HloModuleProto::from_text_file(&good).is_ok());
        let bad = dir.join("bad.hlo.txt");
        std::fs::write(&bad, "this is not HLO").unwrap();
        assert!(HloModuleProto::from_text_file(&bad).is_err());
    }

    #[test]
    fn execution_is_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&XlaComputation).unwrap();
        assert!(exe.execute::<Literal>(&[]).is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
    }
}
