//! Train-step latency per model through PJRT (the §IV training-cost
//! analysis): BitPruning's per-step overhead vs a frozen-bits step on
//! the same artifact, and the transfer-vs-execute split the L3 perf
//! iteration optimizes.

use bitprune::model::ModelMeta;
use bitprune::runtime::Runtime;
use bitprune::tensor::HostTensor;
use bitprune::util::bench::Bench;
use bitprune::util::rng::Rng;

fn main() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("mlp_meta.json").exists() {
        eprintln!("SKIP train_step bench: run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu(&dir).unwrap();
    let mut b = Bench::new();
    let mut rng = Rng::new(3);

    for model in ["mlp", "alexnet_s", "resnet_s", "mobilenet_s"] {
        let meta_path = dir.join(format!("{model}_meta.json"));
        if !meta_path.exists() {
            continue;
        }
        let meta = ModelMeta::load(&meta_path).unwrap();
        let init = rt.load(&meta.init_artifact()).unwrap();
        let train = rt.load(&meta.train_artifact()).unwrap();
        let eval = rt.load(&meta.eval_artifact()).unwrap();

        let params = init.run(&[HostTensor::scalar_u32(0)]).unwrap();
        let momenta: Vec<HostTensor> =
            params.iter().map(|p| HostTensor::zeros_f32(p.dims())).collect();
        let nl = meta.num_quant_layers;
        let bits = HostTensor::full_f32(&[nl], 8.0);
        let lam = HostTensor::full_f32(&[nl], 1.0 / (8.0 * 2.0 * nl as f32));
        let xdim: usize = meta.input_shape.iter().product();
        let x = HostTensor::f32(
            &[meta.batch_size]
                .iter()
                .chain(meta.input_shape.iter())
                .copied()
                .collect::<Vec<_>>(),
            (0..meta.batch_size * xdim)
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect(),
        )
        .unwrap();
        let y = HostTensor::i32(
            &[meta.batch_size],
            (0..meta.batch_size)
                .map(|_| rng.below(meta.num_classes as u64) as i32)
                .collect(),
        )
        .unwrap();

        let mk_args = |mask: f32| {
            let mut args: Vec<HostTensor> = Vec::new();
            args.extend(params.iter().cloned());
            args.extend(momenta.iter().cloned());
            args.push(bits.clone());
            args.push(bits.clone());
            args.push(lam.clone());
            args.push(lam.clone());
            args.push(x.clone());
            args.push(y.clone());
            args.push(HostTensor::scalar_f32(0.01));
            args.push(HostTensor::scalar_f32(1.0));
            args.push(HostTensor::scalar_f32(1.0));
            args.push(HostTensor::scalar_f32(mask));
            args
        };

        let samples = meta.batch_size as f64;
        let learn_args = mk_args(1.0);
        b.run_elems(&format!("train_step/{model}/learn-bits"), samples, || {
            train.run(&learn_args).unwrap()
        });
        let frozen_args = mk_args(0.0);
        b.run_elems(&format!("train_step/{model}/frozen-bits"), samples, || {
            train.run(&frozen_args).unwrap()
        });

        let mut eval_args: Vec<HostTensor> = params.clone();
        eval_args.push(bits.clone());
        eval_args.push(bits.clone());
        eval_args.push(x.clone());
        eval_args.push(y.clone());
        b.run_elems(&format!("eval_step/{model}"), samples, || {
            eval.run(&eval_args).unwrap()
        });

        let s = train.stats();
        println!(
            "  {model}: exec {:.1}us/step, transfer {:.1}us/step ({}% of total)",
            s.total_exec_nanos as f64 / s.executions as f64 / 1e3,
            s.total_transfer_nanos as f64 / s.executions as f64 / 1e3,
            (100 * s.total_transfer_nanos / (s.total_exec_nanos + s.total_transfer_nanos).max(1))
        );
    }

    b.flush_jsonl();
}
