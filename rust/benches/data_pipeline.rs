//! Data-pipeline throughput: synthetic sample generation, batch
//! assembly and augmentation.  The pipeline must stay far off the
//! critical path (train_step dominates); this bench verifies that and
//! feeds the L3 perf iteration log.

use bitprune::data::{self, Loader, Split};
use bitprune::util::bench::Bench;

fn main() {
    let mut b = Bench::new();

    for name in ["synthcifar", "synthcifar-hard", "blobs", "spirals"] {
        let ds = data::build(name, 7).unwrap();
        let elems: usize = ds.input_shape().iter().product();
        let mut buf = vec![0.0f32; elems];
        let mut i = 0usize;
        b.run_elems(&format!("sample/{name}"), elems as f64, || {
            i = (i + 1) % ds.len(Split::Train);
            ds.sample(Split::Train, i, &mut buf)
        });
    }

    let ds = data::build("synthcifar", 7).unwrap();
    for (label, augment) in [("plain", false), ("augmented", true)] {
        let mut loader = Loader::new(ds.as_ref(), Split::Train, 32, augment, 0);
        let per_batch = 32.0 * 16.0 * 16.0 * 3.0;
        b.run_elems(&format!("batch32/synthcifar/{label}"), per_batch, || {
            loader.next_batch().unwrap()
        });
    }

    // Epoch-scale: full shuffled epoch of batches.
    let mut loader = Loader::new(ds.as_ref(), Split::Train, 32, true, 0);
    let n = loader.batches_per_epoch();
    b.run(&format!("epoch/synthcifar/{n}-batches"), || {
        for _ in 0..n {
            loader.next_batch().unwrap();
        }
    });

    b.flush_jsonl();
}
