//! End-to-end coordinator benchmark: steps/second of the full training
//! loop (data pipeline + PJRT step + metric recording + phase machine),
//! the headline number for the perf pass, plus the γ-sweep driver cost
//! that Tables II-VI pay per run.

use bitprune::config::{PlanKind, RunConfig};
use bitprune::coordinator::run_experiment;
use bitprune::runtime::Runtime;
use bitprune::util::bench::{Bench, BenchConfig};

fn main() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("mlp_meta.json").exists() {
        eprintln!("SKIP end_to_end bench: run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu(&dir).unwrap();
    // Whole-run iterations are seconds each; keep samples small.
    let mut b = Bench::with_config(BenchConfig {
        warmup_iters: 1,
        max_samples: 5,
        time_budget: std::time::Duration::from_secs(60),
    });

    let base = RunConfig {
        model: "mlp".into(),
        dataset: "blobs".into(),
        learn_steps: 30,
        finetune_steps: 10,
        eval_every: 1000, // exclude periodic evals from the loop cost
        artifact_dir: dir.to_string_lossy().into_owned(),
        out_dir: std::env::temp_dir()
            .join("bitprune-bench")
            .to_string_lossy()
            .into_owned(),
        ..Default::default()
    };

    let steps = (base.learn_steps + base.finetune_steps) as f64;
    let r = b.run_elems("e2e/mlp-blobs/40-steps", steps, || {
        run_experiment(&rt, &base).unwrap()
    });
    println!(
        "  -> {:.1} steps/s end-to-end (mlp, batch {})",
        r.throughput().unwrap_or(0.0),
        32
    );

    // Frozen-bits variant isolates the BitPruning overhead end to end.
    let mut frozen = base.clone();
    frozen.plan = PlanKind::FixedBits;
    frozen.init_bits = 8.0;
    b.run_elems("e2e/mlp-blobs/frozen-bits", steps, || {
        run_experiment(&rt, &frozen).unwrap()
    });

    if dir.join("resnet_s_meta.json").exists() {
        let mut cnn = base.clone();
        cnn.model = "resnet_s".into();
        cnn.dataset = "synthcifar".into();
        cnn.learn_steps = 10;
        cnn.finetune_steps = 0;
        cnn.eval_every = 1000;
        // warmup >= 1 so the first sample does not absorb the one-time
        // artifact compilation (~30s for resnet_s).
        let mut bb = Bench::with_config(BenchConfig {
            warmup_iters: 1,
            max_samples: 3,
            time_budget: std::time::Duration::from_secs(60),
        });
        let r = bb.run_elems("e2e/resnet_s-synthcifar/10-steps", 10.0, || {
            run_experiment(&rt, &cnn).unwrap()
        });
        println!(
            "  -> {:.2} steps/s end-to-end (resnet_s, batch 32)",
            r.throughput().unwrap_or(0.0)
        );
        bb.flush_jsonl();
    }

    b.flush_jsonl();
}
