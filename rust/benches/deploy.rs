//! Deploy-subsystem benchmarks: what it costs to ship and swap a
//! model.
//!
//! * `deploy/freeze`, `deploy/serialize`, `deploy/parse`,
//!   `deploy/instantiate` — the artifact pipeline on the mlp shapes
//!   (32→256→128→10), elems = parameter count.
//! * `deploy/artifact_load_file` — `Artifact::load` from disk (parse +
//!   validate + checksum).
//! * `deploy/swap_under_load_latency` — request latencies from a
//!   micro-batching server while the registry hot-swaps versions every
//!   few hundred responses; its p99 is the **swap-stall** number the
//!   acceptance criterion tracks (JSONL records carry `p99_s`).
//! * `deploy/steady_state_latency` — the same load with no swaps, for
//!   the stall comparison.
//!
//! `scripts/bench.sh` merges the records into `BENCH_deploy.json`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use bitprune::deploy::{freeze, Artifact, ModelRegistry};
use bitprune::serve::{synthetic_mlp, ServeConfig, Server};
use bitprune::util::bench::{append_jsonl, Bench, BenchResult};
use bitprune::util::rng::Rng;

/// Closed-loop client load; returns per-request latency seconds.
/// When `swap_every > 0`, the main thread republishes (alternating two
/// versions) each time that many more responses have landed.
fn run_load(
    registry: &Arc<ModelRegistry>,
    nets: &[Arc<bitprune::infer::IntNet>],
    requests: usize,
    swap_every: usize,
) -> Vec<f64> {
    let server = Server::start_registry(
        Arc::clone(registry),
        ServeConfig {
            threads: 2,
            max_batch: 16,
            batch_window: Duration::from_micros(200),
            max_queue: 8192,
            ..ServeConfig::default()
        },
    )
    .expect("server starts");
    let clients = 4usize;
    let din = registry.input_dim();
    let served = AtomicUsize::new(0);
    let mut lats: Vec<f64> = Vec::with_capacity(requests);
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..clients {
            let handle = server.handle();
            let served = &served;
            let n_req = requests / clients + usize::from(c < requests % clients);
            joins.push(scope.spawn(move || {
                let mut rng = Rng::new(0xDE9 + c as u64);
                let mut out = Vec::with_capacity(n_req);
                for _ in 0..n_req {
                    let x: Vec<f32> =
                        (0..din).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                    let t = Instant::now();
                    handle.infer(x).expect("request served");
                    out.push(t.elapsed().as_secs_f64());
                    served.fetch_add(1, Ordering::Relaxed);
                }
                out
            }));
        }
        if swap_every > 0 {
            let mut next = swap_every;
            let mut flip = 0usize;
            'swaps: while next < requests {
                while served.load(Ordering::Relaxed) < next {
                    if joins.iter().all(|j| j.is_finished()) {
                        break 'swaps; // clients died; don't spin forever
                    }
                    std::thread::yield_now();
                }
                flip += 1;
                let net = &nets[flip % nets.len()];
                registry
                    .publish(Arc::clone(net), &format!("swap-{flip}"))
                    .expect("swap publish");
                next += swap_every;
            }
        }
        for j in joins {
            lats.extend(j.join().expect("client panicked"));
        }
    });
    let stats = server.shutdown();
    assert_eq!(stats.requests as usize, requests);
    lats
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = Bench::new();

    let net = Arc::new(synthetic_mlp(0xDE9107, 4, 8));
    let params: f64 =
        (32 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10) as f64;

    // --- artifact pipeline ------------------------------------------------
    let art = freeze(&net, "bench-mlp");
    let bytes = art.to_bytes();
    b.run_elems("deploy/freeze", params, || freeze(&net, "bench-mlp"));
    b.run_elems("deploy/serialize", params, || art.to_bytes());
    b.run_elems("deploy/parse", params, || {
        Artifact::from_bytes(&bytes).expect("valid artifact parses")
    });
    b.run_elems("deploy/instantiate", params, || {
        art.instantiate().expect("artifact instantiates")
    });

    let dir = std::env::temp_dir().join("bitprune-deploy-bench");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("bench.bpma");
    art.save(&path).expect("artifact saves");
    b.run_elems("deploy/artifact_load_file", params, || {
        Artifact::load(&path).expect("artifact loads")
    });

    // --- swap under load --------------------------------------------------
    // Same request budget with and without mid-traffic swaps; the p99
    // delta is the stall a version swap costs a live client.
    let requests = if quick { 512 } else { 2048 };
    let alt = Arc::new(synthetic_mlp(0x517E, 4, 8));
    let nets = vec![Arc::clone(&net), alt];

    let steady_reg = Arc::new(ModelRegistry::new(Arc::clone(&net), "v1").unwrap());
    let steady = run_load(&steady_reg, &nets, requests, 0);
    let steady = BenchResult::from_samples("deploy/steady_state_latency", steady, None);
    println!("{}", steady.report());

    let swap_reg = Arc::new(ModelRegistry::new(Arc::clone(&net), "v1").unwrap());
    let swap_every = requests / 8;
    let swapped = run_load(&swap_reg, &nets, requests, swap_every);
    let swapped =
        BenchResult::from_samples("deploy/swap_under_load_latency", swapped, None);
    println!("{}", swapped.report());
    println!(
        "  -> swap-stall p99: {:.0}us swapped vs {:.0}us steady ({} swaps over {requests} requests)",
        swapped.percentile(99.0) * 1e6,
        steady.percentile(99.0) * 1e6,
        swap_reg.active_version() - 1,
    );

    b.flush_jsonl();
    append_jsonl(&[steady, swapped]);
}
