//! Integer-inference fast-path benchmarks: blocked i64 GEMM vs the
//! retained scalar reference, and word-level bitpack vs the
//! byte-at-a-time reference.  The acceptance numbers for the fast-path
//! subsystem live here (forward >= 3x at batch 64 / 256x256 / 4-bit;
//! pack+unpack >= 2x at 4 bits); each pair prints its measured speedup.

use bitprune::bitpack;
use bitprune::infer::{simd, ConvGeom, IntConv2d, IntDense};
use bitprune::quant::Codebook;
use bitprune::util::bench::Bench;
use bitprune::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

fn speedup(b: &Bench, fast: &str, slow: &str) {
    if let (Some(f), Some(s)) = (b.result(fast), b.result(slow)) {
        println!("  -> {fast}: {:.2}x vs ref", s.mean / f.mean);
    }
}

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(0x1147);

    // Headline: IntDense::forward, batch 64, 256x256 layer, 4-bit.
    for &(n, din, dout, bits) in &[(64usize, 256usize, 256usize, 4u32), (64, 256, 256, 8)] {
        let x = rand_vec(&mut rng, n * din);
        let w = rand_vec(&mut rng, din * dout);
        let bias = rand_vec(&mut rng, dout);
        let layer =
            IntDense::new("bench", &w, din, dout, &bias, bits, bits, true).unwrap();
        let macs = (n * din * dout) as f64;
        let tag = format!("{n}x{din}x{dout}/{bits}b");
        b.run_elems(&format!("intnet/forward/{tag}"), macs, || {
            layer.forward(&x, n)
        });
        b.run_elems(&format!("intnet/forward_ref/{tag}"), macs, || {
            layer.forward_ref(&x, n)
        });
        speedup(&b, &format!("intnet/forward/{tag}"), &format!("intnet/forward_ref/{tag}"));
    }

    // Conv2d via im2col: batch 16, 32ch 8x8 plane, 3x3/s1/p1, 64
    // kernels — the packing stage plus the same blocked GEMM, vs the
    // element-at-a-time gather reference.
    {
        let g = ConvGeom { cin: 32, h: 8, w: 8, cout: 64, kh: 3, kw: 3, stride: 1, pad: 1 };
        let n = 16usize;
        let x = rand_vec(&mut rng, n * g.in_features());
        let w = rand_vec(&mut rng, g.patch_len() * g.cout);
        let bias = rand_vec(&mut rng, g.cout);
        let layer = IntConv2d::new("bench-c", &w, g, &bias, 4, 4, true).unwrap();
        let macs = (n * g.macs_per_sample()) as f64;
        let tag = "16x32x8x8k3/4b";
        b.run_elems(&format!("intnet/conv_forward/{tag}"), macs, || {
            layer.forward(&x, n)
        });
        b.run_elems(&format!("intnet/conv_forward_ref/{tag}"), macs, || {
            layer.forward_ref(&x, n)
        });
        speedup(
            &b,
            &format!("intnet/conv_forward/{tag}"),
            &format!("intnet/conv_forward_ref/{tag}"),
        );
    }

    // Per-output-channel GEMM: row-varying codes (bits cycling 2/4/8)
    // through the same blocked kernel vs the scalar grouped reference,
    // plus the per-layer kernel at the same shape for the granularity
    // overhead.
    {
        let (n, din, dout) = (64usize, 256usize, 256usize);
        let x = rand_vec(&mut rng, n * din);
        let w = rand_vec(&mut rng, din * dout);
        let bias = rand_vec(&mut rng, dout);
        let ch_bits: Vec<f32> =
            (0..dout).map(|j| [2.0f32, 4.0, 8.0][j % 3]).collect();
        let grouped =
            IntDense::new_grouped("bench-g", &w, din, dout, &bias, &ch_bits, 4, true)
                .unwrap();
        let macs = (n * din * dout) as f64;
        let tag = format!("{n}x{din}x{dout}/ch248");
        b.run_elems(&format!("intnet/forward_grouped/{tag}"), macs, || {
            grouped.forward(&x, n)
        });
        b.run_elems(&format!("intnet/forward_grouped_ref/{tag}"), macs, || {
            grouped.forward_ref(&x, n)
        });
        speedup(
            &b,
            &format!("intnet/forward_grouped/{tag}"),
            &format!("intnet/forward_grouped_ref/{tag}"),
        );
    }

    // Shift-add GEMM (non-uniform codebooks: the inner multiply
    // replaced by shifts/adds over (sign, exponent) codes) vs the
    // retained scalar multiply reference — per-layer PoT and grouped
    // APoT at the headline shape.
    {
        let (n, din, dout) = (64usize, 256usize, 256usize);
        let x = rand_vec(&mut rng, n * din);
        let w = rand_vec(&mut rng, din * dout);
        let bias = rand_vec(&mut rng, dout);
        let macs = (n * din * dout) as f64;

        let pot = IntDense::new_cbk(
            "bench-s", &w, din, dout, &bias, 4, 4, true, Codebook::PowerOfTwo,
        )
        .unwrap();
        assert!(pot.uses_shift_gemm());
        let tag = format!("{n}x{din}x{dout}/pot4b");
        b.run_elems(&format!("intnet/forward_shift/{tag}"), macs, || {
            pot.forward(&x, n)
        });
        b.run_elems(&format!("intnet/forward_shift_ref/{tag}"), macs, || {
            pot.forward_ref(&x, n)
        });
        speedup(
            &b,
            &format!("intnet/forward_shift/{tag}"),
            &format!("intnet/forward_shift_ref/{tag}"),
        );

        let ch_bits: Vec<f32> =
            (0..dout).map(|j| [2.0f32, 4.0, 8.0][j % 3]).collect();
        let apot = IntDense::new_grouped_cbk(
            "bench-sg", &w, din, dout, &bias, &ch_bits, 4, true,
            Codebook::AdditivePot2,
        )
        .unwrap();
        assert!(apot.uses_shift_gemm());
        let tag = format!("{n}x{din}x{dout}/apot-ch248");
        b.run_elems(&format!("intnet/forward_shift_grouped/{tag}"), macs, || {
            apot.forward(&x, n)
        });
        b.run_elems(&format!("intnet/forward_shift_grouped_ref/{tag}"), macs, || {
            apot.forward_ref(&x, n)
        });
        speedup(
            &b,
            &format!("intnet/forward_shift_grouped/{tag}"),
            &format!("intnet/forward_shift_grouped_ref/{tag}"),
        );
    }

    // Narrow-lane / SIMD dispatch pairs: the same headline shapes, with
    // the `_ref` leg pinned to the portable scalar kernel via
    // `simd::force_portable` — so `speedup_vs_ref` isolates the pure
    // SIMD/dispatch win (both legs are bit-identical; asserted below).
    // The toggle is confined to this single-threaded bench main, so no
    // other code can observe the pinned state.
    {
        println!("kernel dispatch: {}", simd::describe());
        let (n, din, dout) = (64usize, 256usize, 256usize);
        let x = rand_vec(&mut rng, n * din);
        let w = rand_vec(&mut rng, din * dout);
        let bias = rand_vec(&mut rng, dout);
        let macs = (n * din * dout) as f64;
        let ch_bits: Vec<f32> =
            (0..dout).map(|j| [2.0f32, 4.0, 8.0][j % 3]).collect();

        let dense =
            IntDense::new("bench-v", &w, din, dout, &bias, 4, 4, true).unwrap();
        let grouped = IntDense::new_grouped(
            "bench-vg", &w, din, dout, &bias, &ch_bits, 4, true,
        )
        .unwrap();
        let pot = IntDense::new_cbk(
            "bench-vs", &w, din, dout, &bias, 4, 4, true, Codebook::PowerOfTwo,
        )
        .unwrap();

        // Bit-identity across the dispatch toggle, checked before timing.
        for l in [&dense, &grouped, &pot] {
            let native = l.forward(&x, n);
            simd::force_portable(true);
            let portable = l.forward(&x, n);
            simd::force_portable(false);
            assert!(
                native.iter().zip(&portable).all(|(a, b)| a.to_bits() == b.to_bits()),
                "dispatch paths diverged"
            );
        }

        for (name, layer) in [
            (format!("intnet/forward_simd/{n}x{din}x{dout}/4b"), &dense),
            (format!("intnet/forward_simd_grouped/{n}x{din}x{dout}/ch248"), &grouped),
            (format!("intnet/forward_shift_simd/{n}x{din}x{dout}/pot4b"), &pot),
        ] {
            b.run_elems(&name, macs, || layer.forward(&x, n));
            simd::force_portable(true);
            let ref_name = {
                let (head, tail) = name.split_once('/').unwrap();
                let (kind, shape) = tail.split_once('/').unwrap();
                format!("{head}/{kind}_ref/{shape}")
            };
            b.run_elems(&ref_name, macs, || layer.forward(&x, n));
            simd::force_portable(false);
            speedup(&b, &name, &ref_name);
        }
    }

    // Group-boundary-aligned fused pack vs its scalar reference:
    // 256 channels x 256 weights, bits cycling 2/4/8.
    {
        let (groups, size) = (256usize, 256usize);
        let xs = rand_vec(&mut rng, groups * size);
        let bits: Vec<u32> = (0..groups).map(|g| [2u32, 4, 8][g % 3]).collect();
        let total = (groups * size) as f64;
        b.run_elems("bitpack/pack_groups/256x256/ch248", total, || {
            bitpack::pack_groups(&xs, size, &bits).unwrap()
        });
        b.run_elems("bitpack/pack_groups_ref/256x256/ch248", total, || {
            bitpack::pack_groups_ref(&xs, size, &bits).unwrap()
        });
        speedup(
            &b,
            "bitpack/pack_groups/256x256/ch248",
            "bitpack/pack_groups_ref/256x256/ch248",
        );
    }

    // Word-level pack/unpack vs scalar reference at 4 bits (and 8 for
    // the byte-aligned best case of the old path).
    let size = 1usize << 16;
    let xs = rand_vec(&mut rng, size);
    for &bits in &[4u32, 8] {
        let packed = bitpack::pack(&xs, bits).unwrap();
        b.run_elems(&format!("bitpack/pack/{size}/{bits}b"), size as f64, || {
            bitpack::pack(&xs, bits).unwrap()
        });
        b.run_elems(&format!("bitpack/pack_ref/{size}/{bits}b"), size as f64, || {
            bitpack::pack_ref(&xs, bits).unwrap()
        });
        speedup(
            &b,
            &format!("bitpack/pack/{size}/{bits}b"),
            &format!("bitpack/pack_ref/{size}/{bits}b"),
        );
        b.run_elems(&format!("bitpack/unpack_codes/{size}/{bits}b"), size as f64, || {
            bitpack::unpack_codes(&packed)
        });
        b.run_elems(
            &format!("bitpack/unpack_codes_ref/{size}/{bits}b"),
            size as f64,
            || bitpack::unpack_codes_ref(&packed),
        );
        speedup(
            &b,
            &format!("bitpack/unpack_codes/{size}/{bits}b"),
            &format!("bitpack/unpack_codes_ref/{size}/{bits}b"),
        );
    }

    b.flush_jsonl();
}
