//! Serving-engine benchmarks on the mlp artifact shapes
//! (32→256→128→10, python/compile/models.py): the pooled,
//! buffer-reusing `ServeEngine` against per-call `IntNet::forward`
//! (fresh Vec per layer, scoped thread spawn per large GEMM), plus the
//! full micro-batching server round trip under closed-loop client
//! load.  `scripts/bench.sh` merges the JSONL records into
//! `BENCH_serve.json` with `speedup_vs_ref` pairs — the acceptance
//! number for the serve subsystem is `serve/forward/*` beating
//! `serve/forward_ref/*`.

use std::sync::Arc;
use std::time::Duration;

use bitprune::serve::{synthetic_mlp, ServeConfig, ServeEngine, Server};
use bitprune::util::bench::Bench;
use bitprune::util::rng::Rng;

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(0x5E4E);

    let net = Arc::new(synthetic_mlp(0x5E4E, 4, 8));
    // MACs per sample across 32x256 + 256x128 + 128x10.
    let macs_per_sample: f64 = (32 * 256 + 256 * 128 + 128 * 10) as f64;
    let mut engine = ServeEngine::new(0);

    // Engine (persistent pool + ping-pong scratch) vs per-call forward
    // (the `_ref` baseline) at serving-typical batch sizes.
    for &n in &[1usize, 8, 64] {
        let x: Vec<f32> =
            (0..n * 32).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let tag = format!("mlp/bs{n}");
        let elems = macs_per_sample * n as f64;
        b.run_elems(&format!("serve/forward/{tag}"), elems, || {
            engine.forward(&net, &x, n).len()
        });
        b.run_elems(&format!("serve/forward_ref/{tag}"), elems, || {
            net.forward(&x, n)
        });
        if let (Some(f), Some(s)) = (
            b.result(&format!("serve/forward/{tag}")),
            b.result(&format!("serve/forward_ref/{tag}")),
        ) {
            println!("  -> serve/forward/{tag}: {:.2}x vs per-call", s.mean / f.mean);
        }
    }

    // Full server round trip: 8 closed-loop clients x 32 requests per
    // iteration through the micro-batching queue.
    let (clients, per_client) = (8usize, 32usize);
    let server = Server::start(
        Arc::clone(&net),
        ServeConfig {
            threads: 0,
            max_batch: clients,
            batch_window: Duration::from_micros(100),
            ..ServeConfig::default()
        },
    )
    .expect("server starts");
    let pools: Vec<Vec<Vec<f32>>> = (0..clients)
        .map(|_| {
            (0..per_client)
                .map(|_| (0..32).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                .collect()
        })
        .collect();
    let total = (clients * per_client) as f64;
    b.run_elems("serve/server/8clients_x32req", total, || {
        std::thread::scope(|scope| {
            for pool in &pools {
                let handle = server.handle();
                scope.spawn(move || {
                    for x in pool {
                        handle.infer(x.clone()).expect("request served");
                    }
                });
            }
        });
    });
    let stats = server.shutdown();
    println!(
        "  -> server saw {} requests in {} batches (mean batch {:.1})",
        stats.requests,
        stats.batches,
        stats.mean_batch()
    );

    b.flush_jsonl();
}
