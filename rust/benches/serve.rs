//! Serving-engine benchmarks on the mlp artifact shapes
//! (32→256→128→10, python/compile/models.py): the pooled,
//! buffer-reusing `ServeEngine` against per-call `IntNet::forward`
//! (fresh Vec per layer, scoped thread spawn per large GEMM), plus the
//! full micro-batching server round trip under closed-loop client
//! load.  `scripts/bench.sh` merges the JSONL records into
//! `BENCH_serve.json` with `speedup_vs_ref` pairs — the acceptance
//! number for the serve subsystem is `serve/forward/*` beating
//! `serve/forward_ref/*`.
//!
//! Failure-path numbers (tracked by `scripts/bench_compare.sh`):
//!
//! * `serve/server/overload_shed` — 4x-over-capacity bursts against a
//!   tiny bounded queue with a 1ms deadline and `drop-expired`
//!   shedding; measures how fast the server *resolves* an overloaded
//!   burst (every request served or typed-shed, none lingering).
//! * `serve/server/swap_storm` — closed-loop client latencies while
//!   the registry republishes every few dozen responses; its p99 is
//!   the tail cost of living through a publish storm.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use bitprune::deploy::ModelRegistry;
use bitprune::serve::{
    synthetic_mlp, ServeConfig, ServeEngine, Server, ShedPolicy,
};
use bitprune::util::bench::{append_jsonl, Bench, BenchResult};
use bitprune::util::rng::Rng;

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(0x5E4E);

    let net = Arc::new(synthetic_mlp(0x5E4E, 4, 8));
    // MACs per sample across 32x256 + 256x128 + 128x10.
    let macs_per_sample: f64 = (32 * 256 + 256 * 128 + 128 * 10) as f64;
    let mut engine = ServeEngine::new(0);

    // Engine (persistent pool + ping-pong scratch) vs per-call forward
    // (the `_ref` baseline) at serving-typical batch sizes.
    for &n in &[1usize, 8, 64] {
        let x: Vec<f32> =
            (0..n * 32).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let tag = format!("mlp/bs{n}");
        let elems = macs_per_sample * n as f64;
        b.run_elems(&format!("serve/forward/{tag}"), elems, || {
            engine.forward(&net, &x, n).len()
        });
        b.run_elems(&format!("serve/forward_ref/{tag}"), elems, || {
            net.forward(&x, n)
        });
        if let (Some(f), Some(s)) = (
            b.result(&format!("serve/forward/{tag}")),
            b.result(&format!("serve/forward_ref/{tag}")),
        ) {
            println!("  -> serve/forward/{tag}: {:.2}x vs per-call", s.mean / f.mean);
        }
    }

    // Full server round trip: 8 closed-loop clients x 32 requests per
    // iteration through the micro-batching queue.
    let (clients, per_client) = (8usize, 32usize);
    let server = Server::start(
        Arc::clone(&net),
        ServeConfig {
            threads: 0,
            max_batch: clients,
            batch_window: Duration::from_micros(100),
            ..ServeConfig::default()
        },
    )
    .expect("server starts");
    let pools: Vec<Vec<Vec<f32>>> = (0..clients)
        .map(|_| {
            (0..per_client)
                .map(|_| (0..32).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                .collect()
        })
        .collect();
    let total = (clients * per_client) as f64;
    b.run_elems("serve/server/8clients_x32req", total, || {
        std::thread::scope(|scope| {
            for pool in &pools {
                let handle = server.handle();
                scope.spawn(move || {
                    for x in pool {
                        handle.infer(x.clone()).expect("request served");
                    }
                });
            }
        });
    });
    let stats = server.shutdown();
    println!(
        "  -> server saw {} requests in {} batches (mean batch {:.1})",
        stats.requests,
        stats.batches,
        stats.mean_batch()
    );

    // Overload shedding: 256-request bursts against a 64-slot queue
    // with a 1ms deadline.  The measured work is full resolution of
    // the burst — admission rejections, deadline sheds and serves all
    // land as typed results before the iteration ends.
    let shed_server = Server::start(
        Arc::clone(&net),
        ServeConfig {
            threads: 0,
            max_batch: 16,
            batch_window: Duration::from_micros(100),
            max_queue: 64,
            deadline: Some(Duration::from_millis(1)),
            shed_policy: ShedPolicy::DropExpired,
        },
    )
    .expect("server starts");
    let burst: Vec<Vec<f32>> = (0..256)
        .map(|_| (0..32).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();
    b.run_elems("serve/server/overload_shed", 256.0, || {
        let handle = shed_server.handle();
        let pending: Vec<_> = burst
            .iter()
            .filter_map(|x| handle.submit(x.clone()).ok())
            .collect();
        let mut served = 0usize;
        for rx in pending {
            if let Ok(Ok(_)) = rx.recv() {
                served += 1;
            }
        }
        served
    });
    let stats = shed_server.shutdown();
    println!(
        "  -> overload: {} served / {} shed ({} queue-full, {} deadline) in {} batches",
        stats.requests,
        stats.shed(),
        stats.shed_queue_full,
        stats.shed_expired,
        stats.batches
    );

    // Swap storm: per-request latency under closed-loop load while the
    // registry republishes every requests/32 responses.  The p99 of
    // the sample set is the number bench_compare.sh tracks.
    let quick = std::env::args().any(|a| a == "--quick");
    let requests = if quick { 512 } else { 2048 };
    let alt = Arc::new(synthetic_mlp(0x517F, 4, 8));
    let registry =
        Arc::new(ModelRegistry::new(Arc::clone(&net), "v1").expect("registry"));
    let storm_server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig {
            threads: 0,
            max_batch: 16,
            batch_window: Duration::from_micros(100),
            ..ServeConfig::default()
        },
    )
    .expect("server starts");
    let clients = 4usize;
    let served = AtomicUsize::new(0);
    let mut lats: Vec<f64> = Vec::with_capacity(requests);
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..clients {
            let handle = storm_server.handle();
            let served = &served;
            let n_req = requests / clients + usize::from(c < requests % clients);
            joins.push(scope.spawn(move || {
                let mut rng = Rng::new(0x570 + c as u64);
                let mut out = Vec::with_capacity(n_req);
                for _ in 0..n_req {
                    let x: Vec<f32> =
                        (0..32).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                    let t = Instant::now();
                    handle.infer(x).expect("request served");
                    out.push(t.elapsed().as_secs_f64());
                    served.fetch_add(1, Ordering::Relaxed);
                }
                out
            }));
        }
        let swap_every = requests / 32;
        let mut next = swap_every;
        let mut flip = 0usize;
        'storm: while next < requests {
            while served.load(Ordering::Relaxed) < next {
                if joins.iter().all(|j| j.is_finished()) {
                    break 'storm;
                }
                std::thread::yield_now();
            }
            flip += 1;
            let n = if flip % 2 == 0 { &net } else { &alt };
            registry
                .publish(Arc::clone(n), &format!("storm-{flip}"))
                .expect("storm publish");
            next += swap_every;
        }
        for j in joins {
            lats.extend(j.join().expect("client panicked"));
        }
    });
    let stats = storm_server.shutdown();
    let storm = BenchResult::from_samples("serve/server/swap_storm", lats, None);
    println!("{}", storm.report());
    // p99 via the shared telemetry histogram — the same implementation
    // (and bucket resolution) a scrape of the serve endpoint reports.
    println!(
        "  -> swap storm: {} swaps crossed the batcher, p99 {:.0}us",
        stats.swaps,
        storm.latency_histogram().quantile(0.99) * 1e6
    );

    b.flush_jsonl();
    append_jsonl(&[storm]);
}
