//! Quantizer micro-benchmarks: the rust mirror and the compiled Pallas
//! fake-quant artifact (L1 kernel through PJRT), across sizes and
//! bitlengths.  Supports the §IV training-cost analysis (quant overhead
//! per element) and the L1 perf iteration log in EXPERIMENTS.md.

use bitprune::quant;
use bitprune::runtime::Runtime;
use bitprune::tensor::HostTensor;
use bitprune::util::bench::Bench;
use bitprune::util::rng::Rng;

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(1);

    // Rust mirror across sizes: QuantPlan fast path vs the retained
    // scalar reference (before/after for the fast-path subsystem).
    for &size in &[1usize << 10, 1 << 14, 1 << 18] {
        let xs: Vec<f32> = (0..size).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        b.run_elems(&format!("rust/fake_quant/{size}"), size as f64, || {
            let mut v = xs.clone();
            quant::fake_quant_slice(&mut v, 4.3);
            v
        });
        b.run_elems(&format!("rust/fake_quant_ref/{size}"), size as f64, || {
            let mut v = xs.clone();
            quant::fake_quant_slice_ref(&mut v, 4.3);
            v
        });
    }

    // Integer vs interpolated bitlengths: the alpha == 0 specialization
    // skips the second grid entirely, so integer n is ~2x lighter.
    let xs: Vec<f32> = (0..1 << 14).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    for &n in &[4.0f32, 4.5] {
        b.run_elems(&format!("rust/fake_quant/n={n}"), (1 << 14) as f64, || {
            let mut v = xs.clone();
            quant::fake_quant_slice(&mut v, n);
            v
        });
    }

    // Plan reuse: amortize minmax + scale across repeated applications
    // over a fixed range (the deployment-side calibrated case).
    let plan = quant::QuantPlan::from_slice(&xs, 4.0);
    b.run_elems("rust/quantplan_apply/16384", (1 << 14) as f64, || {
        let mut v = xs.clone();
        plan.apply(&mut v);
        v
    });

    // Fused quantize+pack (word-level) vs the scalar reference packer.
    b.run_elems("rust/pack_fused/16384/4b", (1 << 14) as f64, || {
        bitprune::bitpack::pack(&xs, 4).unwrap()
    });
    b.run_elems("rust/pack_fused_ref/16384/4b", (1 << 14) as f64, || {
        bitprune::bitpack::pack_ref(&xs, 4).unwrap()
    });

    // Selection + cost accounting (coordinator hot helpers).
    let bits: Vec<f32> = (0..64).map(|_| rng.range_f32(1.0, 8.0)).collect();
    b.run("rust/select_integer_bits/64", || quant::select_integer_bits(&bits));

    // Compiled L1 kernel through PJRT (includes transfer overhead — the
    // number the coordinator actually pays).
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("fake_quant.hlo.txt").exists() {
        let rt = Runtime::cpu(&dir).unwrap();
        let exe = rt.load("fake_quant").unwrap();
        let xs: Vec<f32> = (0..4096).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let x = HostTensor::f32(&[4096], xs).unwrap();
        let n = HostTensor::scalar_f32(4.3);
        b.run_elems("pjrt/fake_quant/4096", 4096.0, || {
            exe.run(&[x.clone(), n.clone()]).unwrap()
        });

        let qmm = rt.load("quant_matmul").unwrap();
        let a = HostTensor::f32(&[64, 128], vec![0.1; 64 * 128]).unwrap();
        let w = HostTensor::f32(&[128, 96], vec![0.1; 128 * 96]).unwrap();
        b.run_elems(
            "pjrt/quant_matmul/64x128x96",
            (64 * 128 * 96) as f64,
            || {
                qmm.run(&[
                    a.clone(),
                    w.clone(),
                    HostTensor::scalar_f32(4.0),
                    HostTensor::scalar_f32(4.0),
                ])
                .unwrap()
            },
        );
    } else {
        eprintln!("SKIP pjrt benches: run `make artifacts` first");
    }

    b.flush_jsonl();
}
