//! Accelerator-model benchmarks (Table VIII machinery): evaluation cost
//! of each analytical model and the full evaluate_all sweep, plus a
//! printed mini Table VIII at representative bitlengths so `cargo bench`
//! output doubles as a smoke regeneration of the table's shape.

use bitprune::accel;
use bitprune::model::ModelMeta;
use bitprune::util::bench::Bench;
use bitprune::util::json;

/// A resnet_s-shaped meta without needing artifacts on disk.
fn synthetic_meta(layers: usize) -> ModelMeta {
    let mut layer_objs = Vec::new();
    for i in 0..layers {
        layer_objs.push(format!(
            r#"{{"name": "conv{i}", "kind": "conv", "weight_elems": {we},
                "act_in_elems": {ae}, "macs": {macs}, "cin": 64, "cout": 64,
                "kernel": 3, "out_spatial": 8}}"#,
            we = 36864 + i * 1000,
            ae = 4096,
            macs = 2359296
        ));
    }
    let meta = format!(
        r#"{{"tag": "synth", "model": "synth", "batch_size": 32,
            "input_shape": [16,16,3], "num_classes": 10,
            "num_quant_layers": {layers}, "num_params": 0,
            "param_names": [], "param_shapes": [],
            "layers": [{}], "momentum": 0.9, "weight_decay": 0.0005,
            "n_min": 1.0, "n_max": 16.0}}"#,
        layer_objs.join(",")
    );
    ModelMeta::from_json(&json::parse(&meta).unwrap()).unwrap()
}

fn main() {
    let mut b = Bench::new();
    for &nl in &[8usize, 16, 64] {
        let meta = synthetic_meta(nl);
        let bw: Vec<f32> = (0..nl).map(|i| 2.0 + (i % 4) as f32).collect();
        let ba: Vec<f32> = (0..nl).map(|i| 3.0 + (i % 3) as f32).collect();
        b.run(&format!("accel/evaluate_all/{nl}-layers"), || {
            accel::evaluate_all(&meta, &bw, &ba)
        });
    }

    let meta = synthetic_meta(16);
    for model in accel::all_models() {
        let bw = vec![3.0f32; 16];
        let ba = vec![4.0f32; 16];
        b.run(&format!("accel/{}/16-layers", model.name()), || {
            accel::evaluate(model.as_ref(), &meta, &bw, &ba)
        });
    }

    // Shape smoke: print the mini Table VIII at 3/4 bits.
    println!("\nmini Table VIII (16-layer synthetic net, W=3b A=4b):");
    for r in accel::evaluate_all(&meta, &vec![3.0; 16], &vec![4.0; 16]) {
        println!(
            "  {:<10} perf {:>6} mem {:.2}x",
            r.accel,
            r.speedup.map_or("-".into(), |s| format!("{s:.2}x")),
            r.mem_ratio
        );
    }

    b.flush_jsonl();
}
