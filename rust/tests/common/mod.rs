//! Shared helpers for the integration tests.
//!
//! Integration tests need the AOT artifacts; when they are absent (bare
//! `cargo test` before `make artifacts`) the tests SKIP with a notice
//! instead of failing, so the pure-rust test suite stays runnable.

use std::path::PathBuf;

pub fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("fake_quant.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!(
            "SKIP: artifacts not built (run `make artifacts`) — looked in {}",
            dir.display()
        );
        None
    }
}

#[macro_export]
macro_rules! require_artifacts {
    () => {
        match common::artifact_dir() {
            Some(d) => d,
            None => return,
        }
    };
}
