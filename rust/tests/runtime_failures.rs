//! Failure-injection tests: the runtime and coordinator must fail
//! loudly and informatively, never silently mis-train.

mod common;

use bitprune::config::RunConfig;
use bitprune::coordinator::Trainer;
use bitprune::runtime::Runtime;
use bitprune::tensor::HostTensor;

fn temp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("bitprune-failures").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn missing_artifact_dir_is_an_error() {
    match Runtime::cpu("/nonexistent/bitprune-artifacts") {
        Ok(_) => panic!("expected error for missing artifact dir"),
        Err(err) => assert!(err.to_string().contains("make artifacts"), "{err}"),
    }
}

#[test]
fn garbage_hlo_text_is_rejected() {
    let dir = temp_dir("garbage");
    std::fs::write(dir.join("bad.hlo.txt"), "this is not HLO").unwrap();
    let rt = Runtime::cpu(&dir).unwrap();
    match rt.load("bad") {
        Ok(_) => panic!("garbage HLO must not compile"),
        Err(err) => assert!(err.to_string().contains("bad"), "{err}"),
    }
}

#[test]
fn truncated_hlo_text_is_rejected() {
    let Some(src) = common::artifact_dir() else { return };
    let text = std::fs::read_to_string(src.join("fake_quant.hlo.txt")).unwrap();
    let dir = temp_dir("truncated");
    std::fs::write(dir.join("trunc.hlo.txt"), &text[..text.len() / 2]).unwrap();
    let rt = Runtime::cpu(&dir).unwrap();
    assert!(rt.load("trunc").is_err());
}

#[test]
fn wrong_argument_count_fails() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let exe = rt.load("fake_quant").unwrap();
    // fake_quant wants (x[4096], n); give it just x.
    let x = HostTensor::f32(&[4096], vec![0.0; 4096]).unwrap();
    assert!(exe.run(&[x]).is_err());
}

#[test]
fn wrong_argument_shape_fails() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let exe = rt.load("fake_quant").unwrap();
    let x = HostTensor::f32(&[16], vec![0.0; 16]).unwrap();
    let n = HostTensor::scalar_f32(4.0);
    assert!(exe.run(&[x, n]).is_err());
}

#[test]
fn corrupt_meta_json_is_rejected() {
    let Some(src) = common::artifact_dir() else { return };
    let dir = temp_dir("badmeta");
    // Valid HLO artifacts, corrupted meta.
    for f in ["mlp_init.hlo.txt", "mlp_train.hlo.txt", "mlp_eval.hlo.txt"] {
        std::fs::copy(src.join(f), dir.join(f)).unwrap();
    }
    std::fs::write(dir.join("mlp_meta.json"), "{ not json").unwrap();
    let rt = Runtime::cpu(&dir).unwrap();
    let cfg = RunConfig {
        model: "mlp".into(),
        dataset: "blobs".into(),
        artifact_dir: dir.to_string_lossy().into_owned(),
        ..Default::default()
    };
    assert!(Trainer::new(&rt, &cfg).is_err());
}

#[test]
fn meta_param_mismatch_detected() {
    let Some(src) = common::artifact_dir() else { return };
    let dir = temp_dir("mismatch-meta");
    for f in ["mlp_init.hlo.txt", "mlp_train.hlo.txt", "mlp_eval.hlo.txt"] {
        std::fs::copy(src.join(f), dir.join(f)).unwrap();
    }
    // Claim fewer params than the init artifact produces.
    let meta = std::fs::read_to_string(src.join("mlp_meta.json")).unwrap();
    let doctored = meta
        .replace("\"num_params\": 6", "\"num_params\": 4")
        .replace(
            "\"0/b\", \"0/w\", \"1/b\", \"1/w\", \"2/b\", \"2/w\"",
            "\"0/b\", \"0/w\", \"1/b\", \"1/w\"",
        );
    std::fs::write(dir.join("mlp_meta.json"), doctored).unwrap();
    let rt = Runtime::cpu(&dir).unwrap();
    let cfg = RunConfig {
        model: "mlp".into(),
        dataset: "blobs".into(),
        learn_steps: 2,
        finetune_steps: 1,
        artifact_dir: dir.to_string_lossy().into_owned(),
        ..Default::default()
    };
    // Either meta validation or the init-output arity check must fire.
    let result = Trainer::new(&rt, &cfg).and_then(|t| t.run());
    assert!(result.is_err());
}

#[test]
fn unknown_dataset_rejected_before_any_compile() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let cfg = RunConfig {
        model: "mlp".into(),
        dataset: "imagenet".into(),
        artifact_dir: dir.to_string_lossy().into_owned(),
        ..Default::default()
    };
    assert!(Trainer::new(&rt, &cfg).is_err());
}
