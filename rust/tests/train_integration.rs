//! End-to-end coordinator integration: short real training runs through
//! the compiled artifacts, exercising every phase-machine path, the
//! checkpoint warm start, and the post-training eval session.

mod common;

use bitprune::config::{PlanKind, RunConfig};
use bitprune::coordinator::{run_experiment, Trainer};
use bitprune::quant;
use bitprune::runtime::Runtime;

fn quick_cfg(dir: &std::path::Path, name: &str) -> RunConfig {
    RunConfig {
        name: name.into(),
        model: "mlp".into(),
        dataset: "blobs".into(),
        seed: 11,
        gamma: 1.0,
        learn_steps: 40,
        finetune_steps: 15,
        eval_every: 10,
        artifact_dir: dir.to_string_lossy().into_owned(),
        out_dir: std::env::temp_dir()
            .join("bitprune-it")
            .to_string_lossy()
            .into_owned(),
        ..Default::default()
    }
}

#[test]
fn standard_run_learns_and_selects_integer_bits() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let cfg = quick_cfg(&dir, "it-standard");
    let out = run_experiment(&rt, &cfg).unwrap();

    // Phase structure: non-integer snapshot exists, final bits integral.
    let ni = out.noninteger.as_ref().expect("non-integer stage");
    assert!(out.final_.bits_w.iter().all(|b| b.fract() == 0.0));
    assert!(out.final_.bits_a.iter().all(|b| b.fract() == 0.0));
    // Ceil relation: final int bits within [learned, learned+1].
    for (f, l) in out.final_.bits_w.iter().zip(&ni.bits_w) {
        assert!(*f >= *l - 1e-6 && *f < *l + 1.0 + 1e-6, "ceil relation: {f} vs {l}");
    }
    // Regularizer pulled bits below the 8-bit start.
    assert!(ni.mean_bits_w() < 8.0, "bits did not move: {}", ni.mean_bits_w());
    // Loss decreased over training.
    let first = &out.recorder.steps[0];
    let last = out.recorder.steps.last().unwrap();
    assert!(
        last.task_loss < first.task_loss,
        "task loss did not improve: {} -> {}",
        first.task_loss,
        last.task_loss
    );
    // The blobs task is easy: the quantized model must actually learn.
    assert!(out.final_.accuracy > 0.5, "accuracy {}", out.final_.accuracy);
    // Activation ranges were collected for every layer.
    assert_eq!(out.act_min.len(), out.final_.bits_w.len());
    assert!(out
        .act_min
        .iter()
        .zip(&out.act_max)
        .all(|(mn, mx)| mn <= mx));
}

#[test]
fn fixed_bits_plan_never_moves_bits() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let mut cfg = quick_cfg(&dir, "it-fixed");
    cfg.plan = PlanKind::FixedBits;
    cfg.init_bits = 4.0;
    let out = run_experiment(&rt, &cfg).unwrap();
    assert!(out.noninteger.is_none());
    assert!(out.final_.bits_w.iter().all(|&b| b == 4.0));
    assert!(out.final_.bits_a.iter().all(|&b| b == 4.0));
}

#[test]
fn gamma_zero_keeps_bits_high() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let mut cfg = quick_cfg(&dir, "it-g0");
    cfg.gamma = 0.0;
    let out = run_experiment(&rt, &cfg).unwrap();
    // Without the regularizer the only bit pressure is the task loss,
    // which prefers MORE bits; average bits must stay near the start.
    let ni = out.noninteger.unwrap();
    assert!(
        ni.mean_bits_w() > 6.5,
        "bits collapsed without regularizer: {}",
        ni.mean_bits_w()
    );
}

#[test]
fn stronger_gamma_fewer_bits() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let mut weak = quick_cfg(&dir, "it-weak");
    weak.gamma = 0.25;
    let mut strong = quick_cfg(&dir, "it-strong");
    strong.gamma = 4.0;
    let w = run_experiment(&rt, &weak).unwrap();
    let s = run_experiment(&rt, &strong).unwrap();
    let wb = w.noninteger.unwrap();
    let sb = s.noninteger.unwrap();
    assert!(
        sb.mean_bits_w() + sb.mean_bits_a() < wb.mean_bits_w() + wb.mean_bits_a(),
        "stronger regularizer must reach fewer bits: strong {:.2}/{:.2} vs weak {:.2}/{:.2}",
        sb.mean_bits_w(), sb.mean_bits_a(), wb.mean_bits_w(), wb.mean_bits_a()
    );
}

#[test]
fn early_select_plan_runs() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let mut cfg = quick_cfg(&dir, "it-early");
    cfg.plan = PlanKind::EarlySelect;
    cfg.learn_steps = 10;
    cfg.finetune_steps = 30;
    let out = run_experiment(&rt, &cfg).unwrap();
    assert!(out.noninteger.is_some());
    assert!(out.final_.bits_w.iter().all(|b| b.fract() == 0.0));
}

#[test]
fn checkpoint_warmstart_roundtrip() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let pre_cfg = quick_cfg(&dir, "it-pretrain");
    let ckpt = std::env::temp_dir().join("bitprune-it-warm.bpck");
    let trainer = Trainer::new(&rt, &pre_cfg).unwrap();
    let pre = trainer
        .run_and_checkpoint(Some(ckpt.to_str().unwrap()))
        .unwrap();
    assert!(ckpt.exists());

    let mut warm_cfg = quick_cfg(&dir, "it-warm");
    warm_cfg.plan = PlanKind::Warmstart;
    warm_cfg.warmstart_ckpt = Some(ckpt.to_string_lossy().into_owned());
    warm_cfg.learn_steps = 10;
    warm_cfg.finetune_steps = 5;
    let warm = run_experiment(&rt, &warm_cfg).unwrap();
    // Warm start must not be worse than random-init at step ~0: compare
    // its first periodic eval to the pretrain's first.
    let w0 = warm.recorder.evals.first().unwrap().accuracy;
    let p0 = pre.recorder.evals.first().unwrap().accuracy;
    assert!(
        w0 >= p0 - 0.05,
        "warm start lost pretrained accuracy: {w0} vs {p0}"
    );
}

#[test]
fn eval_session_probes_bitlengths() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let cfg = quick_cfg(&dir, "it-session");
    let trainer = Trainer::new(&rt, &cfg).unwrap();
    let out = trainer.run().unwrap();
    let session = trainer.session(&out.final_params);
    let nl = session.num_layers();
    let hi = session.accuracy(&vec![8.0; nl], &vec![8.0; nl], 4).unwrap();
    let lo = session.accuracy(&vec![1.0; nl], &vec![1.0; nl], 4).unwrap();
    // 1-bit everywhere must hurt vs 8-bit on a trained net.
    assert!(hi >= lo, "8-bit {hi} should be >= 1-bit {lo}");
    assert!((0.0..=1.0).contains(&hi) && (0.0..=1.0).contains(&lo));
}

#[test]
fn profiled_baseline_on_real_network() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let mut cfg = quick_cfg(&dir, "it-prof");
    cfg.plan = PlanKind::FixedBits;
    cfg.init_bits = 16.0;
    let trainer = Trainer::new(&rt, &cfg).unwrap();
    let out = trainer.run().unwrap();
    let session = trainer.session(&out.final_params);
    let mut probe =
        |bw: &[f32], ba: &[f32]| session.accuracy(bw, ba, 2);
    let r = bitprune::baselines::profiled_search(
        session.num_layers(),
        8.0,
        0.05,
        &mut probe,
    )
    .unwrap();
    // Found an assignment at or below the start, never below 1 bit.
    assert!(quant::mean_bits(&r.bits_w) <= 8.0);
    assert!(r.bits_w.iter().chain(&r.bits_a).all(|&b| b >= 1.0));
    assert!(r.probes > 0);
}

#[test]
fn integer_inference_matches_xla_eval() {
    // Deployability: the pure-integer rust engine must agree with the
    // compiled fake-quant eval artifact on a trained network.
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let cfg = quick_cfg(&dir, "it-int-infer");
    let trainer = Trainer::new(&rt, &cfg).unwrap();
    let out = trainer.run().unwrap();
    // Dynamic (per-batch) ranges on purpose: that is the convention the
    // XLA fake-quant eval uses, so the parity claim stays apples to
    // apples.  Calibrated serving invariance is pinned separately in
    // tests/serve_invariance.rs.
    let net = bitprune::infer::IntNet::from_trained(
        trainer.meta(),
        &out.final_params,
        &out.final_.bits_w,
        &out.final_.bits_a,
        None,
    )
    .unwrap();

    let ds = bitprune::data::build(&cfg.dataset, cfg.seed).unwrap();
    let mut loader = bitprune::data::Loader::new(
        ds.as_ref(),
        bitprune::data::Split::Test,
        trainer.meta().batch_size,
        false,
        cfg.seed,
    );
    let mut correct = 0usize;
    let mut total = 0usize;
    for _ in 0..loader.batches_per_epoch() {
        let b = loader.next_batch().unwrap();
        let preds = net.predict(b.x.as_f32().unwrap(), trainer.meta().batch_size);
        for (p, y) in preds.iter().zip(b.y.as_i32().unwrap()) {
            correct += (*p as i32 == *y) as usize;
            total += 1;
        }
    }
    let int_acc = correct as f64 / total as f64;
    assert!(
        (int_acc - out.final_.accuracy).abs() < 0.02,
        "integer path {:.4} vs xla path {:.4}",
        int_acc,
        out.final_.accuracy
    );
    // Packed model smaller than f32 and than uniform 8-bit.
    assert!(net.packed_bytes() * 4 < net.f32_bytes());
}

#[test]
fn parallel_scheduler_runs_experiments() {
    let dir = require_artifacts!();
    let mut a = quick_cfg(&dir, "it-par-a");
    a.learn_steps = 10;
    a.finetune_steps = 5;
    let mut b = a.clone();
    b.name = "it-par-b".into();
    b.gamma = 2.0;
    let outcomes =
        bitprune::coordinator::run_all_parallel(&[a, b], 2).unwrap();
    assert_eq!(outcomes.len(), 2);
    assert_eq!(outcomes[0].name, "it-par-a");
    assert_eq!(outcomes[1].name, "it-par-b");
}

#[test]
fn config_artifact_mismatch_rejected() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let mut cfg = quick_cfg(&dir, "it-mismatch");
    cfg.dataset = "synthcifar".into(); // image data into the MLP artifact
    assert!(Trainer::new(&rt, &cfg).is_err());
}
