//! Deterministic fault-injection suite (`cargo test --features chaos`).
//!
//! Every test here runs with injected faults — dying workers, panicking
//! jobs, panicking batch forwards, wedged batchers, latency spikes,
//! corrupted-logit canaries — and asserts the fleet's hard invariants:
//!
//! 1. **No request is silently lost**: every submit resolves to logits
//!    or a typed `ServeError`, and the stats counters account for every
//!    one of them exactly.
//! 2. **Dead workers are respawned** and post-respawn forwards are
//!    bit-identical to a healthy pool's.
//! 3. **A corrupted (or slow) canary is auto-rolled-back** before it
//!    ever reaches 100% of traffic; the incumbent never stops serving.
//!
//! Injectors are every-Nth-event counters, so fault schedules are a
//! pure function of the event sequence; the seed (`CHAOS_SEED`, pinned
//! in CI) feeds fixture construction.  See `src/serve/chaos.rs`.

#![cfg(feature = "chaos")]

use std::sync::Arc;
use std::time::{Duration, Instant};

use bitprune::deploy::ModelRegistry;
use bitprune::infer::IntNet;
use bitprune::serve::chaos::{corrupted_twin, pinned_seed, Chaos, ChaosConfig};
use bitprune::serve::{
    synthetic_net, CanaryConfig, CanaryOutcome, RetryPolicy, ServeConfig, ServeEngine,
    ServeError, Server, ShedPolicy,
};
use bitprune::util::pool::{PoolError, WorkerPool};
use bitprune::util::rng::Rng;

const DIMS: &[usize] = &[10, 22, 4];

fn fixture(seed: u64) -> Arc<IntNet> {
    Arc::new(synthetic_net(DIMS, seed, 4, 6))
}

fn same(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn no_request_silently_lost_under_full_chaos() {
    // Stalls, forward panics and latency spikes all at once, against a
    // tiny bounded queue with tight deadlines: whatever happens, all
    // 300 submissions must resolve to exactly one typed outcome, and
    // the stats must account for every single one.
    let seed = pinned_seed();
    let net = fixture(seed);
    let registry = Arc::new(ModelRegistry::new(Arc::clone(&net), "v1").unwrap());
    let chaos = Chaos::new(ChaosConfig {
        forward_panic_every: 13,
        stall_every: 5,
        stall: Duration::from_millis(30),
        spike_every: 11,
        spike: Duration::from_millis(1),
        ..ChaosConfig::default()
    });
    let server = Server::start_chaos(
        Arc::clone(&registry),
        ServeConfig {
            threads: 1,
            max_batch: 8,
            batch_window: Duration::from_micros(200),
            max_queue: 64,
            deadline: Some(Duration::from_millis(10)),
            shed_policy: ShedPolicy::DropExpired,
        },
        Arc::clone(&chaos),
    )
    .unwrap();
    let handle = server.handle();
    let mut rng = Rng::new(seed ^ 0xC1);
    let total = 300usize;
    let (mut served, mut queue_full, mut expired, mut panicked) = (0u64, 0u64, 0u64, 0u64);
    let mut pending = Vec::new();
    for _ in 0..total {
        let x: Vec<f32> = (0..DIMS[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        match handle.submit(x) {
            Ok(rx) => pending.push(rx),
            Err(ServeError::QueueFull { .. }) => {
                queue_full += 1;
                // Pace on backpressure so the batcher makes progress
                // and the faults actually interleave with live load.
                std::thread::sleep(Duration::from_micros(200));
            }
            Err(e) => panic!("unexpected admission error: {e:?}"),
        }
    }
    for rx in pending {
        // `recv` erroring would mean the server dropped the request
        // without answering — the one thing that must never happen.
        match rx.recv().expect("request silently lost") {
            Ok(r) => {
                assert_eq!(r.logits.len(), 4);
                served += 1;
            }
            Err(ServeError::DeadlineExpired { .. }) => expired += 1,
            Err(ServeError::WorkerPanic) => panicked += 1,
            Err(e) => panic!("unexpected outcome: {e:?}"),
        }
    }
    assert_eq!(served + queue_full + expired + panicked, total as u64);
    let telemetry = server.telemetry();
    let stats = server.shutdown();
    // The ledger must balance exactly: what clients saw is what the
    // server counted.
    assert_eq!(stats.requests, served);
    assert_eq!(stats.shed_queue_full, queue_full);
    assert_eq!(stats.shed_expired, expired);
    assert_eq!(stats.failed, panicked);
    // And the scrape-able telemetry registry is the same ledger: every
    // counter equals its ServeStats field, even under full chaos.
    let counter = |name: &str, label: Option<(&str, &str)>| -> u64 {
        telemetry
            .snapshot()
            .into_iter()
            .find(|s| {
                s.name == name
                    && label.map_or(true, |(k, v)| {
                        s.labels.iter().any(|(lk, lv)| lk == k && lv == v)
                    })
            })
            .and_then(|s| match s.value {
                bitprune::telemetry::SampleValue::Counter(v) => Some(v),
                _ => None,
            })
            .unwrap_or_else(|| panic!("counter '{name}' missing from registry"))
    };
    assert_eq!(counter("serve_requests_total", None), stats.requests);
    assert_eq!(counter("serve_batches_total", None), stats.batches);
    assert_eq!(counter("serve_swaps_total", None), stats.swaps);
    assert_eq!(
        counter("serve_shed_total", Some(("reason", "queue_full"))),
        stats.shed_queue_full
    );
    assert_eq!(
        counter("serve_shed_total", Some(("reason", "expired"))),
        stats.shed_expired
    );
    assert_eq!(counter("serve_failed_total", None), stats.failed);
    assert!(served > 0, "chaos must not stop the server from serving");
    // The injectors actually fired (the test would be vacuous otherwise).
    assert!(chaos.injected_stalls() > 0, "no stall was injected");
    assert_eq!(
        panicked > 0,
        chaos.injected_forward_panics() > 0,
        "WorkerPanic outcomes must correspond to injected forward panics"
    );
}

#[test]
fn stalled_batcher_sheds_expired_requests_typed() {
    // A batcher wedged on every dequeue (50ms stalls) against 5ms
    // deadlines: every queued request must come back as a typed
    // DeadlineExpired — shed, counted, never silently dropped.
    let net = fixture(pinned_seed());
    let registry = Arc::new(ModelRegistry::new(Arc::clone(&net), "v1").unwrap());
    let chaos = Chaos::new(ChaosConfig {
        stall_every: 1,
        stall: Duration::from_millis(50),
        ..ChaosConfig::default()
    });
    let server = Server::start_chaos(
        Arc::clone(&registry),
        ServeConfig {
            threads: 1,
            max_batch: 64,
            batch_window: Duration::from_micros(200),
            ..ServeConfig::default()
        },
        Arc::clone(&chaos),
    )
    .unwrap();
    let handle = server.handle();
    let deadline = Instant::now() + Duration::from_millis(5);
    let pending: Vec<_> = (0..10)
        .map(|_| handle.submit_with_deadline(vec![0.1; DIMS[0]], deadline).unwrap())
        .collect();
    for rx in pending {
        match rx.recv().expect("request silently lost") {
            Err(ServeError::DeadlineExpired { waited }) => {
                assert!(waited >= Duration::from_millis(5));
            }
            other => panic!("expected deadline shed under stall, got {other:?}"),
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.shed_expired, 10);
    assert_eq!(stats.requests, 0);
    assert!(chaos.injected_stalls() > 0);
}

#[test]
fn injected_job_panics_are_typed_and_exactly_counted() {
    // Every 4th pool job panics: the error is typed with exact counts,
    // the pool is never poisoned, and the schedule is deterministic
    // across rounds (jobs 4,8 then 12,16 — two per round of eight).
    let chaos = Chaos::new(ChaosConfig { job_panic_every: 4, ..ChaosConfig::default() });
    let pool = WorkerPool::with_chaos(2, Some(Arc::clone(&chaos)));
    for round in 1..=3u64 {
        let jobs: Vec<Box<dyn FnOnce() + Send>> =
            (0..8).map(|_| Box::new(|| {}) as Box<dyn FnOnce() + Send>).collect();
        match pool.try_run_scoped(jobs) {
            Err(PoolError::JobPanicked { panicked, jobs }) => {
                assert_eq!(jobs, 8);
                assert_eq!(panicked, 2, "round {round}: every 4th of 8 jobs");
            }
            Ok(()) => panic!("round {round}: injected panics did not surface"),
        }
        assert_eq!(chaos.injected_job_panics(), 2 * round);
    }
    // Caught panics kill jobs, not workers: nothing needed respawning.
    assert_eq!(pool.respawns(), 0);
}

#[test]
fn dying_workers_are_respawned_and_results_stay_correct() {
    // A worker thread exits on every 3rd poll; the pool must replace
    // it (respawns > 0) and every round's results must still be exact
    // — including rounds dispatched into a partially-dead pool.
    let chaos =
        Chaos::new(ChaosConfig { worker_exit_every: 3, ..ChaosConfig::default() });
    let pool = WorkerPool::with_chaos(3, Some(Arc::clone(&chaos)));
    for round in 0..20u64 {
        let mut results = vec![0u64; 12];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = results
            .chunks_mut(2)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for (j, slot) in chunk.iter_mut().enumerate() {
                        *slot = round * 100 + (i * 2 + j) as u64;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        for (k, v) in results.iter().enumerate() {
            assert_eq!(*v, round * 100 + k as u64, "round {round} slot {k}");
        }
    }
    assert!(chaos.injected_exits() > 0, "no worker exit was injected");
    assert!(pool.respawns() > 0, "dead workers were never respawned");
}

#[test]
fn respawned_pool_forwards_big_batches_bit_identical() {
    // A net big enough to cross the pooled-dispatch threshold
    // (n*din*dout >= 2^20), forwarded repeatedly while workers keep
    // dying: every forward must be bit-identical to the healthy
    // per-call reference.
    let seed = pinned_seed();
    let net = synthetic_net(&[256, 512, 10], seed, 4, 6);
    let n = 16usize; // 16*256*512 = 2^21: layer 0 dispatches to the pool
    let mut rng = Rng::new(seed ^ 0xB16);
    let x: Vec<f32> = (0..n * 256).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let want = net.forward(&x, n);
    let chaos =
        Chaos::new(ChaosConfig { worker_exit_every: 3, ..ChaosConfig::default() });
    let mut engine = ServeEngine::with_chaos(4, Some(Arc::clone(&chaos)));
    for i in 0..10 {
        let got = engine.forward(&net, &x, n);
        assert!(same(got, &want), "forward {i} diverged after worker deaths");
    }
    assert!(chaos.injected_exits() > 0);
    assert!(engine.pool().respawns() > 0, "engine pool never respawned a worker");
}

#[test]
fn forward_panics_surface_typed_and_retry_recovers() {
    // Sequential load with every 3rd batch forward panicking.  Plain
    // clients see typed retryable WorkerPanic; a retrying client always
    // lands.  Single-client sequential traffic makes the whole schedule
    // exact: 20 successes need 29 forwards, 9 of which panic.
    let net = fixture(pinned_seed());
    let registry = Arc::new(ModelRegistry::new(Arc::clone(&net), "v1").unwrap());
    let chaos =
        Chaos::new(ChaosConfig { forward_panic_every: 3, ..ChaosConfig::default() });
    let server = Server::start_chaos(
        Arc::clone(&registry),
        ServeConfig {
            threads: 1,
            max_batch: 8,
            batch_window: Duration::from_micros(100),
            ..ServeConfig::default()
        },
        Arc::clone(&chaos),
    )
    .unwrap();
    let handle = server.handle();
    let policy = RetryPolicy::default();
    for _ in 0..20 {
        let (v, logits) =
            handle.infer_with_retry(vec![0.3; DIMS[0]], &policy).expect("retry exhausted");
        assert_eq!(v, 1);
        assert!(same(&logits, &net.forward(&vec![0.3; DIMS[0]], 1)));
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 20);
    assert_eq!(stats.failed, 9, "every 3rd of 29 forwards panicked");
    assert_eq!(chaos.injected_forward_panics(), 9);
    assert!(ServeError::WorkerPanic.is_retryable());
}

#[test]
fn corrupted_canary_rolls_back_before_full_promotion() {
    // The headline invariant: a canary serving corrupted logits (same
    // shape, garbage weights) must be auto-rolled-back on online
    // disagreement — it never becomes the active version, and after
    // resolution 100% of traffic is back on the incumbent.
    let seed = pinned_seed();
    let net = fixture(seed);
    let bad = Arc::new(corrupted_twin(&net, seed ^ 0xBAD));
    // Precondition: the twin really is corrupted (argmaxes disagree).
    let mut rng = Rng::new(seed ^ 0x9E);
    let probes: Vec<Vec<f32>> = (0..64)
        .map(|_| (0..DIMS[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();
    let disagreements = probes
        .iter()
        .filter(|x| {
            let a = net.forward(x, 1);
            let b = bad.forward(x, 1);
            argmax(&a) != argmax(&b)
        })
        .count();
    assert!(disagreements > 6, "twin must disagree well past the 1% gate");

    let registry = Arc::new(ModelRegistry::new(Arc::clone(&net), "good").unwrap());
    let server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig {
            threads: 1,
            max_batch: 8,
            batch_window: Duration::from_micros(200),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let cv = server
        .start_canary(
            Arc::clone(&bad),
            "corrupted",
            CanaryConfig {
                pct: 30,
                window: 16,
                promote_after: 3,
                min_agreement: 0.99,
                max_latency_ratio: 1000.0,
            },
        )
        .unwrap();
    let handle = server.handle();
    let mut resolved = false;
    for _ in 0..800 {
        let x: Vec<f32> = (0..DIMS[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let (v, _) = handle.infer_versioned(x).unwrap();
        assert!(v == 1 || v == cv, "impossible version {v}");
        assert_ne!(
            registry.active_version(),
            cv,
            "corrupted canary must never become active"
        );
        if server.canary_status().is_some_and(|s| s.outcome.is_some()) {
            resolved = true;
            break;
        }
    }
    assert!(resolved, "canary never resolved: {:?}", server.canary_status());
    let status = server.canary_status().unwrap();
    match &status.outcome {
        Some(CanaryOutcome::RolledBack { version, reason }) => {
            assert_eq!(*version, cv);
            assert!(reason.contains("disagreement"), "unexpected reason: {reason}");
        }
        other => panic!("corrupted canary must roll back, got {other:?}"),
    }
    assert_eq!(registry.active_version(), 1);
    assert_eq!(registry.canary_version(), None);
    // Post-rollback: all traffic on the incumbent again.
    for _ in 0..10 {
        let (v, _) = handle.infer_versioned(vec![0.2; DIMS[0]]).unwrap();
        assert_eq!(v, 1);
    }
    let stats = server.shutdown();
    assert_eq!(stats.promotions, 0);
    assert_eq!(stats.rollbacks, 1);
}

#[test]
fn latency_spiked_canary_rolls_back_on_tail_regression() {
    // The canary is a bit-identical twin (agreement is perfect) but
    // chaos injects a 2ms spike into every canary forward: the p99
    // guard must catch it and roll back — a canary can fail on latency
    // alone.
    let seed = pinned_seed();
    let net = fixture(seed);
    let registry = Arc::new(ModelRegistry::new(Arc::clone(&net), "good").unwrap());
    let chaos = Chaos::new(ChaosConfig {
        spike_every: 1,
        spike: Duration::from_millis(2),
        spike_canary_only: true,
        ..ChaosConfig::default()
    });
    let server = Server::start_chaos(
        Arc::clone(&registry),
        ServeConfig {
            threads: 1,
            max_batch: 8,
            batch_window: Duration::from_micros(200),
            ..ServeConfig::default()
        },
        Arc::clone(&chaos),
    )
    .unwrap();
    let cv = server
        .start_canary(
            Arc::clone(&net),
            "slow-twin",
            CanaryConfig {
                pct: 50,
                window: 8,
                promote_after: 1000, // unreachable: latency must decide
                min_agreement: 0.5,
                max_latency_ratio: 3.0,
            },
        )
        .unwrap();
    let handle = server.handle();
    let mut rng = Rng::new(seed ^ 0x1A7);
    let mut resolved = false;
    for _ in 0..600 {
        let x: Vec<f32> = (0..DIMS[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        handle.infer_versioned(x).unwrap();
        if server.canary_status().is_some_and(|s| s.outcome.is_some()) {
            resolved = true;
            break;
        }
    }
    assert!(resolved, "slow canary never resolved: {:?}", server.canary_status());
    match &server.canary_status().unwrap().outcome {
        Some(CanaryOutcome::RolledBack { version, reason }) => {
            assert_eq!(*version, cv);
            assert!(reason.contains("latency"), "unexpected reason: {reason}");
        }
        other => panic!("slow canary must roll back, got {other:?}"),
    }
    assert_eq!(registry.active_version(), 1);
    assert!(chaos.injected_spikes() > 0);
    let stats = server.shutdown();
    assert_eq!(stats.promotions, 0);
    assert_eq!(stats.rollbacks, 1);
}

fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}
