//! Batch-invariance suite: with calibrated activation ranges, a
//! sample's logits are **bit-identical** no matter which batch it is
//! served in — the bugfix this PR pins (dynamic per-batch min/max made
//! logits depend on batch composition) and the property the serve
//! subsystem's micro-batching relies on.  Pure rust — runs without
//! artifacts.

use std::sync::Arc;
use std::time::Duration;

use bitprune::infer::NetScratch;
use bitprune::quant::Codebook;
use bitprune::serve::{synthetic_mlp, synthetic_net, synthetic_net_cbk, ServeConfig, Server};
use bitprune::util::rng::Rng;

fn rand_batch(rng: &mut Rng, n: usize, din: usize) -> Vec<f32> {
    (0..n * din).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

/// Forward `samples` through `net` at batch size `bs` and return the
/// per-sample logits rows in order.
fn logits_at_batch_size(
    net: &bitprune::infer::IntNet,
    samples: &[f32],
    total: usize,
    din: usize,
    out_dim: usize,
    bs: usize,
) -> Vec<Vec<f32>> {
    let mut rows = Vec::with_capacity(total);
    let mut start = 0usize;
    while start < total {
        let n = bs.min(total - start);
        let x = &samples[start * din..(start + n) * din];
        let out = net.forward(x, n);
        for r in 0..n {
            rows.push(out[r * out_dim..(r + 1) * out_dim].to_vec());
        }
        start += n;
    }
    rows
}

#[test]
fn calibrated_logits_bit_identical_across_batch_sizes_1_7_64() {
    // The pinned acceptance criterion: identical per-sample logits for
    // batch sizes {1, 7, 64} over the same 64 inputs.
    let net = synthetic_mlp(0xB11, 4, 6);
    assert!(net.is_calibrated());
    let (din, out_dim) = (32, 10);
    let total = 64;
    let mut rng = Rng::new(0xD474);
    let samples = rand_batch(&mut rng, total, din);

    let r1 = logits_at_batch_size(&net, &samples, total, din, out_dim, 1);
    let r7 = logits_at_batch_size(&net, &samples, total, din, out_dim, 7);
    let r64 = logits_at_batch_size(&net, &samples, total, din, out_dim, 64);
    for (i, ((a, b), c)) in r1.iter().zip(&r7).zip(&r64).enumerate() {
        for (j, ((va, vb), vc)) in a.iter().zip(b).zip(c).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "sample {i} logit {j}: bs1 {va} vs bs7 {vb}"
            );
            assert_eq!(
                va.to_bits(),
                vc.to_bits(),
                "sample {i} logit {j}: bs1 {va} vs bs64 {vc}"
            );
        }
    }
}

#[test]
fn dynamic_ranges_are_batch_dependent_calibration_fixes_it() {
    // Regression shape of the original bug: under per-batch ranges an
    // outlier neighbour stretches the quantization grid and moves the
    // other sample's logits; calibrated ranges remove the dependence.
    let mut rng = Rng::new(0x0DD);
    let mut net = synthetic_net(&[16, 24, 4], 0x0DD, 3, 3);
    let nl = net.layers.len();

    let sample: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut outlier: Vec<f32> = (0..16).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    outlier[3] = 55.0;
    let mut pair = sample.clone();
    pair.extend_from_slice(&outlier);

    // Calibrated (synthetic_net ships calibrated): solo == paired.
    let solo = net.forward(&sample, 1);
    let paired = net.forward(&pair, 2);
    assert!(solo
        .iter()
        .zip(&paired[..4])
        .all(|(a, b)| a.to_bits() == b.to_bits()));

    // Re-pin the ranges to what the dynamic path would have derived
    // from the outlier batch: the same sample's logits move.
    let (lo, hi) = pair.iter().fold(
        (f32::INFINITY, f32::NEG_INFINITY),
        |(lo, hi), &v| (lo.min(v), hi.max(v)),
    );
    net.set_act_ranges(&vec![lo; nl], &vec![hi; nl]).unwrap();
    let shifted = net.forward(&sample, 1);
    assert!(
        solo.iter().zip(&shifted).any(|(a, b)| a.to_bits() != b.to_bits()),
        "stretching the quantization range must move 3-bit logits"
    );
}

#[test]
fn invariance_survives_the_scratch_and_pooled_paths() {
    // forward / forward_into(pool) / forward_ref all agree, calibrated,
    // at every batch size — the serving engine cannot reintroduce batch
    // dependence through its buffers or its worker pool.
    let net = synthetic_net(&[12, 40, 5], 7, 4, 4);
    let pool = bitprune::util::pool::WorkerPool::new(3);
    let mut sc = NetScratch::default();
    let mut rng = Rng::new(21);
    let samples = rand_batch(&mut rng, 13, 12);
    let alloc = net.forward(&samples, 13);
    let scratch = net.forward_into(&samples, 13, &mut sc, Some(&pool));
    assert_eq!(alloc.len(), scratch.len());
    assert!(alloc.iter().zip(scratch).all(|(a, b)| a.to_bits() == b.to_bits()));
    // Layer-level reference path agrees too.
    let mut h = samples.clone();
    for layer in &net.layers {
        h = layer.forward_ref(&h, 13);
    }
    assert!(alloc.iter().zip(&h).all(|(a, b)| a.to_bits() == b.to_bits()));
}

#[test]
fn degenerate_serving_inputs() {
    // Constant batches (zero dynamic range) and all-zero post-ReLU
    // activations must stay finite and batch-invariant.
    let net = synthetic_mlp(5, 4, 4);
    for v in [0.0f32, 1.0, -3.0] {
        let solo = net.forward(&[v; 32], 1);
        let batch = net.forward(&[v; 4 * 32], 4);
        assert!(solo.iter().all(|x| x.is_finite()));
        for r in 0..4 {
            assert!(solo
                .iter()
                .zip(&batch[r * 10..(r + 1) * 10])
                .all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }
}

#[test]
fn invariance_holds_on_the_shift_add_codebook_path() {
    // The shift-add GEMM replaces the inner multiply but reproduces the
    // identical i64 accumulator — calibrated invariance and the
    // scratch/pooled/reference agreement must survive on both
    // non-uniform codebooks (mixed per-layer/grouped fixture).
    for cbk in [Codebook::PowerOfTwo, Codebook::AdditivePot2] {
        let net = synthetic_net_cbk(&[12, 40, 24, 5], 7, 4, 4, cbk);
        assert!(net.layers.iter().all(|l| l.codebook() == cbk));
        let pool = bitprune::util::pool::WorkerPool::new(3);
        let mut sc = NetScratch::default();
        let mut rng = Rng::new(23);
        let samples = rand_batch(&mut rng, 13, 12);
        let alloc = net.forward(&samples, 13);
        let scratch = net.forward_into(&samples, 13, &mut sc, Some(&pool));
        assert!(alloc.iter().zip(scratch).all(|(a, b)| a.to_bits() == b.to_bits()));
        let mut h = samples.clone();
        for layer in &net.layers {
            h = layer.forward_ref(&h, 13);
        }
        assert!(
            alloc.iter().zip(&h).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{cbk:?}: shift-add path diverged from the multiply reference"
        );
        // Batch-invariant like every calibrated net.
        let solo = net.forward(&samples[..12], 1);
        assert!(solo.iter().zip(&alloc[..5]).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}

#[test]
fn server_roundtrip_is_invariant_under_micro_batching_codebook() {
    // End to end through the queue on the PoT fixture: micro-batched
    // answers equal solo forwards on the shift-add path too.
    let net = Arc::new(synthetic_net_cbk(&[8, 20, 12, 3], 99, 4, 5, Codebook::PowerOfTwo));
    let server = Server::start(
        Arc::clone(&net),
        ServeConfig {
            threads: 2,
            max_batch: 16,
            batch_window: Duration::from_millis(3),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let handle = server.handle();
    let mut rng = Rng::new(0x78);
    let samples: Vec<Vec<f32>> = (0..32)
        .map(|_| (0..8).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();
    let pending: Vec<_> = samples
        .iter()
        .map(|s| handle.submit(s.clone()).unwrap())
        .collect();
    for (s, rx) in samples.iter().zip(pending) {
        let got = rx.recv().unwrap().expect("request served, not shed");
        let want = net.forward(s, 1);
        assert!(
            got.logits.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
            "micro-batched codebook answer differs from solo forward"
        );
    }
    server.shutdown();
}

#[test]
fn server_roundtrip_is_invariant_under_micro_batching() {
    // End to end through the queue: interleave two client patterns so
    // requests coalesce into mixed batches; every answer must equal the
    // solo forward.
    let net = Arc::new(synthetic_net(&[8, 20, 3], 99, 4, 5));
    let server = Server::start(
        Arc::clone(&net),
        ServeConfig {
            threads: 2,
            max_batch: 16,
            batch_window: Duration::from_millis(3),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let handle = server.handle();
    let mut rng = Rng::new(0x77);
    let samples: Vec<Vec<f32>> = (0..48)
        .map(|_| (0..8).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();
    let pending: Vec<_> = samples
        .iter()
        .map(|s| handle.submit(s.clone()).unwrap())
        .collect();
    for (s, rx) in samples.iter().zip(pending) {
        let got = rx.recv().unwrap().expect("request served, not shed");
        let want = net.forward(s, 1);
        assert!(
            got.logits.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
            "micro-batched answer differs from solo forward"
        );
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 48);
}
