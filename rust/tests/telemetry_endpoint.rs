//! Integration tests for the telemetry subsystem's public surface:
//! the HTTP scrape endpoint (Prometheus text + JSON snapshot) and the
//! registry's behavior under real worker-pool concurrency.

use std::sync::Arc;

use bitprune::telemetry::{http_get, MetricsServer, Registry};
use bitprune::util::json;
use bitprune::util::pool::WorkerPool;

fn demo_registry() -> Arc<Registry> {
    let r = Arc::new(Registry::new());
    r.counter("demo_requests_total", &[]).add(42);
    r.counter("demo_shed_total", &[("reason", "queue_full")]).add(3);
    r.gauge("demo_queue_depth", &[]).set(7.5);
    let h = r.histogram("demo_batch_size", &[], 1.0);
    for _ in 0..4 {
        h.observe(2);
    }
    r
}

#[test]
fn scraped_prometheus_text_matches_golden() {
    let reg = demo_registry();
    let mut srv =
        MetricsServer::start("127.0.0.1:0", Arc::clone(&reg)).expect("bind");
    let addr = srv.addr().to_string();
    let body = http_get(&addr, "/metrics").expect("scrape /metrics");
    // The full exposition, pinned end-to-end over HTTP: stable sort
    // order, TYPE lines, label rendering, summary quantiles from the
    // verified interpolation (4x observe(2) in bucket [2,3)).
    let golden = "\
# TYPE demo_batch_size summary
demo_batch_size{quantile=\"0.5\"} 2.5
demo_batch_size{quantile=\"0.95\"} 2.95
demo_batch_size{quantile=\"0.99\"} 2.99
demo_batch_size_sum 8
demo_batch_size_count 4
# TYPE demo_queue_depth gauge
demo_queue_depth 7.5
# TYPE demo_requests_total counter
demo_requests_total 42
# TYPE demo_shed_total counter
demo_shed_total{reason=\"queue_full\"} 3
";
    assert_eq!(body, golden);
    srv.shutdown();
}

#[test]
fn scraped_json_roundtrips_through_util_json() {
    let reg = demo_registry();
    let mut srv =
        MetricsServer::start("127.0.0.1:0", Arc::clone(&reg)).expect("bind");
    let addr = srv.addr().to_string();
    let body = http_get(&addr, "/metrics.json").expect("scrape /metrics.json");
    let v = json::parse(&body).expect("endpoint must serve valid JSON");
    let metrics = v.get("metrics").unwrap().as_arr().unwrap();
    assert_eq!(metrics.len(), 4);

    let by_name = |name: &str| {
        metrics
            .iter()
            .find(|m| m.get("name").unwrap().as_str().unwrap() == name)
            .unwrap_or_else(|| panic!("metric '{name}' missing from snapshot"))
    };
    let req = by_name("demo_requests_total");
    assert_eq!(req.get("type").unwrap().as_str().unwrap(), "counter");
    assert_eq!(req.get("value").unwrap().as_f64().unwrap(), 42.0);

    let shed = by_name("demo_shed_total");
    let labels = shed.get("labels").unwrap().as_obj().unwrap();
    assert_eq!(labels.get("reason").unwrap().as_str().unwrap(), "queue_full");

    let gauge = by_name("demo_queue_depth");
    assert_eq!(gauge.get("value").unwrap().as_f64().unwrap(), 7.5);

    let hist = by_name("demo_batch_size");
    assert_eq!(hist.get("type").unwrap().as_str().unwrap(), "histogram");
    assert_eq!(hist.get("count").unwrap().as_usize().unwrap(), 4);
    assert_eq!(hist.get("sum").unwrap().as_f64().unwrap(), 8.0);
    assert_eq!(hist.get("p50").unwrap().as_f64().unwrap(), 2.5);

    // Round trip: re-serializing the parsed tree and re-parsing it
    // reproduces the same structure (util::json's contract).
    let re = json::parse(&v.to_string()).expect("reparse");
    assert_eq!(re.to_string(), v.to_string());
    srv.shutdown();
}

#[test]
fn endpoint_rejects_unknown_paths_and_methods() {
    let reg = demo_registry();
    let mut srv =
        MetricsServer::start("127.0.0.1:0", Arc::clone(&reg)).expect("bind");
    let addr = srv.addr().to_string();
    assert!(http_get(&addr, "/nope").is_err());
    // A healthy route still works on the next connection.
    assert!(http_get(&addr, "/metrics").is_ok());
    srv.shutdown();
}

#[test]
fn pool_hammered_counters_survive_concurrent_scrapes() {
    // Worker threads hammer one counter handle and one histogram while
    // the main thread scrapes mid-flight: every intermediate snapshot
    // must be internally sane, and the final counts exact.
    const ROUNDS: usize = 20;
    const JOBS: usize = 8;
    const INCS: u64 = 500;
    let reg = Arc::new(Registry::new());
    let c = reg.counter("hammer_total", &[]);
    let h = reg.histogram("hammer_values", &[], 1.0);
    let mut srv =
        MetricsServer::start("127.0.0.1:0", Arc::clone(&reg)).expect("bind");
    let addr = srv.addr().to_string();

    let pool = WorkerPool::new(4);
    for _ in 0..ROUNDS {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..JOBS)
            .map(|_| {
                let (c, h) = (Arc::clone(&c), Arc::clone(&h));
                Box::new(move || {
                    for i in 0..INCS {
                        c.inc();
                        h.observe(i % 7);
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        // Scrape between rounds: monotone counter, count == counter.
        let body = http_get(&addr, "/metrics").expect("mid-flight scrape");
        assert!(body.contains("hammer_total"), "{body}");
    }
    let want = (ROUNDS * JOBS) as u64 * INCS;
    assert_eq!(c.get(), want);
    assert_eq!(h.count(), want);
    // sum of (i % 7) over 0..500 per job: 500 = 71*7 + 3 full cycles;
    // 71 cycles of 0+..+6=21 plus remainder 0+1+2.
    let per_job: u64 = 71 * 21 + 3;
    assert_eq!(h.sum(), (per_job * (ROUNDS * JOBS) as u64) as f64);
    let final_text = http_get(&addr, "/metrics").expect("final scrape");
    assert!(final_text.contains(&format!("hammer_total {want}")), "{final_text}");
    srv.shutdown();
}
