//! Dispatch-path parity suite: whatever kernel path the runtime picks
//! (AVX2 / NEON / portable), the forward pass must be **bit-identical**
//! to the scalar `forward_ref` oracle — and to itself with the portable
//! fallback pinned.  This is the binary the CI `dispatch-matrix` job
//! runs under native features, `-C target-feature=+avx2`, and
//! `BITPRUNE_FORCE_PORTABLE=1`.

use bitprune::infer::simd::{self, KernelPath};
use bitprune::infer::{ConvGeom, IntConv2d, IntDense};
use bitprune::quant::Codebook;
use bitprune::util::proptest::check;
use bitprune::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, 1.5)).collect()
}

/// Bitwise comparison of three forwards with a labelled error.
fn expect_identical(
    label: &str,
    want: &[f32],
    native: &[f32],
    portable: &[f32],
) -> Result<(), String> {
    if want.len() != native.len() || want.len() != portable.len() {
        return Err(format!("{label}: length mismatch"));
    }
    for (i, ((w, n), p)) in want.iter().zip(native).zip(portable).enumerate() {
        if w.to_bits() != n.to_bits() {
            return Err(format!("{label}: native elem {i}: {n} vs ref {w}"));
        }
        if w.to_bits() != p.to_bits() {
            return Err(format!("{label}: portable elem {i}: {p} vs ref {w}"));
        }
    }
    Ok(())
}

/// The CI matrix's env override must pin the scalar fallback: when
/// `BITPRUNE_FORCE_PORTABLE` is set truthy, one-time detection resolves
/// Portable no matter what the CPU offers.  On the `+avx2` build leg
/// (and any AVX2 runner) an unforced probe must resolve Avx2.
#[test]
fn env_override_pins_the_ci_matrix_leg() {
    println!("dispatch: {}", simd::describe());
    let forced = std::env::var("BITPRUNE_FORCE_PORTABLE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    if forced {
        assert_eq!(simd::detected_path(), KernelPath::Portable);
        assert_eq!(simd::kernel_path(), KernelPath::Portable);
    } else if cfg!(all(target_arch = "x86_64", target_feature = "avx2")) {
        assert_eq!(simd::detected_path(), KernelPath::Avx2);
    }
}

/// One randomized sweep per layer family — dense, grouped, codebook
/// (per-layer PoT + grouped APoT) and conv — each case comparing the
/// scalar oracle, the natively dispatched forward, and the forward with
/// the portable fallback pinned, all bitwise.  208 cases total.
///
/// This is the **only** test in this binary that touches
/// `simd::force_portable` (the pin is process-global; a second toggling
/// test would race the restore under the parallel test runner).
#[test]
fn all_dispatch_paths_bit_identical_to_forward_ref() {
    // Shapes cross the i16/i32/i64 thresholds: din up to 300 at up to
    // 16-bit operands lands every lane, and dout % 4 != 0 exercises the
    // scalar remainder columns of the blocked kernels.
    check(
        "simd-dispatch-dense",
        64,
        |rng| {
            let n = 1 + rng.below_usize(9);
            let din = 1 + rng.below_usize(300);
            let dout = 1 + rng.below_usize(40);
            let wb = 1 + rng.below(16) as u32;
            let ab = 1 + rng.below(16) as u32;
            let relu = rng.below(2) == 0;
            let x = rand_vec(rng, n * din);
            let w = rand_vec(rng, din * dout);
            let b = rand_vec(rng, dout);
            (n, din, dout, wb, ab, relu, x, w, b)
        },
        |(n, din, dout, wb, ab, relu, x, w, b)| {
            let layer = IntDense::new("d", w, *din, *dout, b, *wb, *ab, *relu)
                .map_err(|e| e.to_string())?;
            let want = layer.forward_ref(x, *n);
            let native = layer.forward(x, *n);
            simd::force_portable(true);
            let portable = layer.forward(x, *n);
            simd::force_portable(false);
            expect_identical(
                &format!("dense ({n},{din},{dout}) bits ({wb},{ab})"),
                &want,
                &native,
                &portable,
            )
        },
    );

    check(
        "simd-dispatch-grouped",
        48,
        |rng| {
            let n = 1 + rng.below_usize(8);
            let din = 1 + rng.below_usize(200);
            let dout = 1 + rng.below_usize(24);
            let ab = 1 + rng.below(16) as u32;
            let relu = rng.below(2) == 0;
            let x = rand_vec(rng, n * din);
            let w = rand_vec(rng, din * dout);
            let b = rand_vec(rng, dout);
            let ch_bits: Vec<f32> =
                (0..dout).map(|_| (1 + rng.below(16)) as f32).collect();
            (n, din, dout, ab, relu, x, w, b, ch_bits)
        },
        |(n, din, dout, ab, relu, x, w, b, ch_bits)| {
            let layer =
                IntDense::new_grouped("g", w, *din, *dout, b, ch_bits, *ab, *relu)
                    .map_err(|e| e.to_string())?;
            let want = layer.forward_ref(x, *n);
            let native = layer.forward(x, *n);
            simd::force_portable(true);
            let portable = layer.forward(x, *n);
            simd::force_portable(false);
            expect_identical(
                &format!("grouped ({n},{din},{dout}) a_bits {ab}"),
                &want,
                &native,
                &portable,
            )
        },
    );

    check(
        "simd-dispatch-codebook",
        48,
        |rng| {
            let n = 1 + rng.below_usize(6);
            let din = 1 + rng.below_usize(120);
            let dout = 1 + rng.below_usize(20);
            // Shift-plan grids need bits >= 2 (half = 2^(bits-1) with a
            // signed part); stay inside the codebook-admissible range.
            let wb = 2 + rng.below(7) as u32;
            let ab = 1 + rng.below(8) as u32;
            let relu = rng.below(2) == 0;
            let grouped = rng.below(2) == 0;
            let cbk = if rng.below(2) == 0 {
                Codebook::PowerOfTwo
            } else {
                Codebook::AdditivePot2
            };
            let x = rand_vec(rng, n * din);
            let w = rand_vec(rng, din * dout);
            let b = rand_vec(rng, dout);
            let ch_bits: Vec<f32> =
                (0..dout).map(|_| (2 + rng.below(7)) as f32).collect();
            (n, din, dout, wb, ab, relu, grouped, cbk, x, w, b, ch_bits)
        },
        |(n, din, dout, wb, ab, relu, grouped, cbk, x, w, b, ch_bits)| {
            let layer = if *grouped {
                IntDense::new_grouped_cbk(
                    "s", w, *din, *dout, b, ch_bits, *ab, *relu, *cbk,
                )
            } else {
                IntDense::new_cbk("s", w, *din, *dout, b, *wb, *ab, *relu, *cbk)
            }
            .map_err(|e| e.to_string())?;
            let want = layer.forward_ref(x, *n);
            let native = layer.forward(x, *n);
            simd::force_portable(true);
            let portable = layer.forward(x, *n);
            simd::force_portable(false);
            expect_identical(
                &format!("cbk {cbk:?} grouped={grouped} ({n},{din},{dout})"),
                &want,
                &native,
                &portable,
            )
        },
    );

    check(
        "simd-dispatch-conv",
        48,
        |rng| {
            let n = 1 + rng.below_usize(3);
            let cin = 1 + rng.below_usize(4);
            let h = 3 + rng.below_usize(6);
            let w = 3 + rng.below_usize(6);
            let cout = 1 + rng.below_usize(8);
            let kh = 1 + rng.below_usize(h.min(3));
            let kw = 1 + rng.below_usize(w.min(3));
            let stride = 1 + rng.below_usize(2);
            let pad = rng.below_usize(2);
            let g = ConvGeom { cin, h, w, cout, kh, kw, stride, pad };
            let wb = 1 + rng.below(16) as u32;
            let ab = 1 + rng.below(16) as u32;
            let relu = rng.below(2) == 0;
            let x = rand_vec(rng, n * g.in_features());
            let wt = rand_vec(rng, g.patch_len() * cout);
            let b = rand_vec(rng, cout);
            (n, g, wb, ab, relu, x, wt, b)
        },
        |(n, g, wb, ab, relu, x, wt, b)| {
            let layer = IntConv2d::new("c", wt, *g, b, *wb, *ab, *relu)
                .map_err(|e| e.to_string())?;
            let want = layer.forward_ref(x, *n);
            let native = layer.forward(x, *n);
            simd::force_portable(true);
            let portable = layer.forward(x, *n);
            simd::force_portable(false);
            expect_identical(&format!("conv {g:?} bits ({wb},{ab})"), &want, &native, &portable)
        },
    );
}
