//! Fast-path parity suite: the blocked i64 GEMM, the word-level
//! bitpacker and the QuantPlan kernel must be **bit-identical** to the
//! retained `*_ref` scalar implementations, across every bitlength and
//! at unaligned lengths.  Pure rust — runs without artifacts.

use bitprune::bitpack;
use bitprune::infer::{ConvGeom, IntConv2d, IntDense};
use bitprune::quant;
use bitprune::util::proptest::check;
use bitprune::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, 1.5)).collect()
}

#[test]
fn pack_roundtrip_all_bitlengths_unaligned() {
    // pack -> unpack_codes -> repack reproduces the byte stream, for
    // every bitlength 1..=16 at lengths that straddle word boundaries.
    check(
        "fastpath-pack-roundtrip",
        256,
        |rng| {
            let bits = 1 + rng.below(16) as u32;
            let len = 1 + rng.below_usize(300);
            (rand_vec(rng, len), bits)
        },
        |(xs, bits)| {
            let p = bitpack::pack(xs, *bits).map_err(|e| e.to_string())?;
            let codes = bitpack::unpack_codes(&p);
            if codes.len() != xs.len() {
                return Err("length mismatch".into());
            }
            let max_code = (1u32 << bits) - 1;
            if codes.iter().any(|&c| c > max_code) {
                return Err(format!("code exceeds {max_code}"));
            }
            // Dequantized values survive a second quantize+pack exactly.
            let vals = bitpack::unpack(&p);
            let p2 = bitpack::pack(&vals, *bits).map_err(|e| e.to_string())?;
            if bitpack::unpack_codes(&p2).len() != codes.len() {
                return Err("repack length mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn word_packer_bitstream_matches_scalar_ref() {
    check(
        "fastpath-pack-parity",
        256,
        |rng| {
            let bits = 1 + rng.below(16) as u32;
            let len = 1 + rng.below_usize(300);
            (rand_vec(rng, len), bits)
        },
        |(xs, bits)| {
            let fast = bitpack::pack(xs, *bits).map_err(|e| e.to_string())?;
            let slow = bitpack::pack_ref(xs, *bits).map_err(|e| e.to_string())?;
            if fast != slow {
                return Err(format!("byte stream differs at {bits} bits"));
            }
            if bitpack::unpack_codes(&fast) != bitpack::unpack_codes_ref(&fast) {
                return Err("unpack_codes differs".into());
            }
            let (f, r) = (bitpack::unpack(&fast), bitpack::unpack_ref(&fast));
            if f.iter().zip(&r).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err("unpack differs".into());
            }
            Ok(())
        },
    );
}

#[test]
fn quantplan_kernel_matches_scalar_ref() {
    check(
        "fastpath-quant-parity",
        256,
        |rng| {
            let len = 1 + rng.below_usize(300);
            // Half the cases integer bitlengths (alpha == 0 shortcut),
            // half fractional; scale varies over orders of magnitude.
            let n = if rng.below(2) == 0 {
                (1 + rng.below(16)) as f32
            } else {
                rng.range_f32(1.0, 16.0)
            };
            let scale = 10f32.powi(rng.below(5) as i32 - 2);
            let xs: Vec<f32> =
                (0..len).map(|_| rng.normal_f32(0.0, scale)).collect();
            (xs, n)
        },
        |(xs, n)| {
            let mut fast = xs.clone();
            quant::fake_quant_slice(&mut fast, *n);
            let mut slow = xs.clone();
            quant::fake_quant_slice_ref(&mut slow, *n);
            for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
                if f.to_bits() != s.to_bits() {
                    return Err(format!("elem {i}: {f} vs {s} (n={n})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn grouped_gemm_matches_scalar_ref() {
    // Row-varying per-channel codes through the blocked GEMM vs the
    // scalar grouped baseline: random shapes, random per-channel
    // bitlengths, both activation conventions.
    check(
        "fastpath-grouped-gemm-parity",
        48,
        |rng| {
            let n = 1 + rng.below_usize(12);
            let din = 1 + rng.below_usize(48);
            let dout = 1 + rng.below_usize(40);
            let ab = 1 + rng.below(16) as u32;
            let relu = rng.below(2) == 0;
            let calibrated = rng.below(2) == 0;
            let x = rand_vec(rng, n * din);
            let w = rand_vec(rng, din * dout);
            let b = rand_vec(rng, dout);
            let ch_bits: Vec<f32> =
                (0..dout).map(|_| (1 + rng.below(16)) as f32).collect();
            (n, din, dout, ab, relu, calibrated, x, w, b, ch_bits)
        },
        |(n, din, dout, ab, relu, calibrated, x, w, b, ch_bits)| {
            let mut layer =
                IntDense::new_grouped("g", w, *din, *dout, b, ch_bits, *ab, *relu)
                    .map_err(|e| e.to_string())?;
            if *calibrated {
                layer.set_act_range(-2.0, 2.0);
            }
            let fast = layer.forward(x, *n);
            let slow = layer.forward_ref(x, *n);
            for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
                if f.to_bits() != s.to_bits() {
                    return Err(format!(
                        "({n},{din},{dout}) a_bits {ab} elem {i}: {f} vs {s}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn grouped_uniform_bits_match_per_layer_bitwise() {
    // The granularity parity pin: a PerOutputChannel layer whose
    // channels all share the per-layer bitlength *and plan* must be
    // bit-identical to the PerLayer layer — fast and _ref paths.  din
    // is byte-aligned so the per-layer bitstream of the transposed
    // weights doubles as the group-aligned layout.
    check(
        "fastpath-granularity-parity",
        48,
        |rng| {
            let n = 1 + rng.below_usize(8);
            let din = 8 * (1 + rng.below_usize(6)); // byte-aligned groups
            let dout = 1 + rng.below_usize(24);
            let wb = 1 + rng.below(16) as u32;
            let ab = 1 + rng.below(16) as u32;
            let x = rand_vec(rng, n * din);
            let w = rand_vec(rng, din * dout);
            let b = rand_vec(rng, dout);
            (n, din, dout, wb, ab, x, w, b)
        },
        |(n, din, dout, wb, ab, x, w, b)| {
            let per_layer = IntDense::new("pl", w, *din, *dout, b, *wb, *ab, true)
                .map_err(|e| e.to_string())?;
            // Same plan (min/max is permutation-invariant), channel-major
            // codes, reinterpreted as byte-aligned per-channel spans.
            let mut wt = vec![0.0f32; din * dout];
            for i in 0..*din {
                for j in 0..*dout {
                    wt[j * din + i] = w[i * dout + j];
                }
            }
            let flat = bitpack::pack(&wt, *wb).map_err(|e| e.to_string())?;
            let params: Vec<(u32, f32, f32)> =
                vec![(flat.bits, flat.lmin, flat.scale); *dout];
            let groups = bitpack::PackedGroups::from_raw(*din, &params, flat.data.clone())
                .map_err(|e| e.to_string())?;
            let grouped = IntDense::from_packed_groups(
                "gr",
                groups,
                *din,
                *dout,
                b.clone(),
                *ab,
                true,
                None,
            )
            .map_err(|e| e.to_string())?;
            let want = per_layer.forward(x, *n);
            let got = grouped.forward(x, *n);
            let got_ref = grouped.forward_ref(x, *n);
            for (i, ((a, g), r)) in want.iter().zip(&got).zip(&got_ref).enumerate() {
                if a.to_bits() != g.to_bits() {
                    return Err(format!(
                        "fast elem {i}: per-layer {a} vs grouped {g} ({wb}b)"
                    ));
                }
                if a.to_bits() != r.to_bits() {
                    return Err(format!(
                        "ref elem {i}: per-layer {a} vs grouped {r} ({wb}b)"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn grouped_packer_matches_scalar_ref() {
    check(
        "fastpath-grouped-pack-parity",
        128,
        |rng| {
            let groups = 1 + rng.below_usize(12);
            let size = 1 + rng.below_usize(150);
            let xs = rand_vec(rng, groups * size);
            let bits: Vec<u32> =
                (0..groups).map(|_| 1 + rng.below(16) as u32).collect();
            (xs, size, bits)
        },
        |(xs, size, bits)| {
            let fast = bitpack::pack_groups(xs, *size, bits).map_err(|e| e.to_string())?;
            let slow =
                bitpack::pack_groups_ref(xs, *size, bits).map_err(|e| e.to_string())?;
            if fast != slow {
                return Err("grouped byte streams differ".into());
            }
            for g in 0..fast.n_groups() {
                if fast.group_codes(g) != fast.group_codes_ref(g) {
                    return Err(format!("group {g} code unpack differs"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn conv_im2col_matches_scalar_ref() {
    // The im2col fast path (span-copying packer + blocked GEMM) vs the
    // element-at-a-time gather reference: random geometries — strides,
    // pads (including pad deeper than the kernel's interior reach),
    // kernels larger than the padded plane are regenerated away by
    // construction below.
    check(
        "fastpath-conv-parity",
        48,
        |rng| {
            let n = 1 + rng.below_usize(4);
            let cin = 1 + rng.below_usize(4);
            let h = 3 + rng.below_usize(8);
            let w = 3 + rng.below_usize(8);
            let cout = 1 + rng.below_usize(8);
            let kh = 1 + rng.below_usize(h.min(3));
            let kw = 1 + rng.below_usize(w.min(3));
            let stride = 1 + rng.below_usize(2);
            let pad = rng.below_usize(3);
            let g = ConvGeom { cin, h, w, cout, kh, kw, stride, pad };
            let wb = 1 + rng.below(16) as u32;
            let ab = 1 + rng.below(16) as u32;
            let relu = rng.below(2) == 0;
            let x = rand_vec(rng, n * g.in_features());
            let wt = rand_vec(rng, g.patch_len() * cout);
            let b = rand_vec(rng, cout);
            (n, g, wb, ab, relu, x, wt, b)
        },
        |(n, g, wb, ab, relu, x, wt, b)| {
            let layer = IntConv2d::new("c", wt, *g, b, *wb, *ab, *relu)
                .map_err(|e| e.to_string())?;
            let fast = layer.forward(x, *n);
            let slow = layer.forward_ref(x, *n);
            for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
                if f.to_bits() != s.to_bits() {
                    return Err(format!("{g:?} bits ({wb},{ab}) elem {i}: {f} vs {s}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn conv_1x1_stride1_matches_dense_bitwise() {
    // A 1×1/stride-1/pad-0 convolution is a dense layer applied at
    // every pixel: im2col is the identity, so the conv over [n,h,w,cin]
    // must be bit-identical to the dense layer over [n·h·w, cin] rows —
    // at per-layer AND per-output-kernel granularity (the dynamic-range
    // plans see the same value multiset, hence the same min/max).
    check(
        "fastpath-conv-1x1-dense",
        48,
        |rng| {
            let n = 1 + rng.below_usize(4);
            let cin = 1 + rng.below_usize(12);
            let h = 1 + rng.below_usize(6);
            let w = 1 + rng.below_usize(6);
            let cout = 1 + rng.below_usize(10);
            let wb = 1 + rng.below(16) as u32;
            let ab = 1 + rng.below(16) as u32;
            let grouped = rng.below(2) == 0;
            let relu = rng.below(2) == 0;
            let x = rand_vec(rng, n * h * w * cin);
            let wt = rand_vec(rng, cin * cout);
            let b = rand_vec(rng, cout);
            let ch_bits: Vec<f32> =
                (0..cout).map(|_| (1 + rng.below(16)) as f32).collect();
            (n, cin, h, w, cout, wb, ab, grouped, relu, x, wt, b, ch_bits)
        },
        |(n, cin, h, w, cout, wb, ab, grouped, relu, x, wt, b, ch_bits)| {
            let g = ConvGeom {
                cin: *cin, h: *h, w: *w, cout: *cout,
                kh: 1, kw: 1, stride: 1, pad: 0,
            };
            let (conv, dense) = if *grouped {
                (
                    IntConv2d::new_grouped("c", wt, g, b, ch_bits, *ab, *relu)
                        .map_err(|e| e.to_string())?,
                    IntDense::new_grouped("d", wt, *cin, *cout, b, ch_bits, *ab, *relu)
                        .map_err(|e| e.to_string())?,
                )
            } else {
                (
                    IntConv2d::new("c", wt, g, b, *wb, *ab, *relu)
                        .map_err(|e| e.to_string())?,
                    IntDense::new("d", wt, *cin, *cout, b, *wb, *ab, *relu)
                        .map_err(|e| e.to_string())?,
                )
            };
            let rows = n * h * w;
            let cv = conv.forward(x, *n);
            let dv = dense.forward(x, rows);
            if cv.len() != dv.len() {
                return Err("length mismatch".into());
            }
            for (i, (c, d)) in cv.iter().zip(&dv).enumerate() {
                if c.to_bits() != d.to_bits() {
                    return Err(format!(
                        "grouped={grouped} ({n},{cin},{h}x{w},{cout}) elem {i}: conv {c} vs dense {d}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn shift_add_gemm_matches_multiply_ref_dense() {
    // The codebook tentpole pin: the shift-add GEMM (multiply-free
    // inner loop over (sign, exponent) codes) vs the retained multiply
    // reference, bit for bit — random shapes, both non-uniform
    // codebooks, per-layer and grouped, calibrated and not.
    check(
        "fastpath-shift-gemm-parity",
        128,
        |rng| {
            let n = 1 + rng.below_usize(10);
            let din = 1 + rng.below_usize(48);
            let dout = 1 + rng.below_usize(40);
            let wb = 1 + rng.below(16) as u32;
            let ab = 1 + rng.below(16) as u32;
            let cbk = if rng.below(2) == 0 {
                quant::Codebook::PowerOfTwo
            } else {
                quant::Codebook::AdditivePot2
            };
            let grouped = rng.below(2) == 0;
            let relu = rng.below(2) == 0;
            let calibrated = rng.below(2) == 0;
            let x = rand_vec(rng, n * din);
            let w = rand_vec(rng, din * dout);
            let b = rand_vec(rng, dout);
            let ch_bits: Vec<f32> =
                (0..dout).map(|_| (1 + rng.below(16)) as f32).collect();
            (n, din, dout, wb, ab, cbk, grouped, relu, calibrated, x, w, b, ch_bits)
        },
        |(n, din, dout, wb, ab, cbk, grouped, relu, calibrated, x, w, b, ch_bits)| {
            let mut layer = if *grouped {
                IntDense::new_grouped_cbk(
                    "sg", w, *din, *dout, b, ch_bits, *ab, *relu, *cbk,
                )
            } else {
                IntDense::new_cbk("s", w, *din, *dout, b, *wb, *ab, *relu, *cbk)
            }
            .map_err(|e| e.to_string())?;
            if !layer.uses_shift_gemm() {
                return Err("non-uniform codebook layer must build a shift plan".into());
            }
            if *calibrated {
                layer.set_act_range(-2.0, 2.0);
            }
            let fast = layer.forward(x, *n);
            let slow = layer.forward_ref(x, *n);
            for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
                if f.to_bits() != s.to_bits() {
                    return Err(format!(
                        "{cbk:?} grouped={grouped} ({n},{din},{dout}) bits \
                         ({wb},{ab}) elem {i}: {f} vs {s}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn shift_add_gemm_matches_multiply_ref_conv() {
    // Same pin through the im2col lowering: random conv geometries on
    // both codebooks, per-layer and per-output-kernel.
    check(
        "fastpath-shift-conv-parity",
        96,
        |rng| {
            let n = 1 + rng.below_usize(3);
            let cin = 1 + rng.below_usize(4);
            let h = 3 + rng.below_usize(6);
            let w = 3 + rng.below_usize(6);
            let cout = 1 + rng.below_usize(8);
            let kh = 1 + rng.below_usize(h.min(3));
            let kw = 1 + rng.below_usize(w.min(3));
            let stride = 1 + rng.below_usize(2);
            let pad = rng.below_usize(2);
            let g = ConvGeom { cin, h, w, cout, kh, kw, stride, pad };
            let wb = 1 + rng.below(16) as u32;
            let ab = 1 + rng.below(16) as u32;
            let cbk = if rng.below(2) == 0 {
                quant::Codebook::PowerOfTwo
            } else {
                quant::Codebook::AdditivePot2
            };
            let grouped = rng.below(2) == 0;
            let relu = rng.below(2) == 0;
            let x = rand_vec(rng, n * g.in_features());
            let wt = rand_vec(rng, g.patch_len() * cout);
            let b = rand_vec(rng, cout);
            let ch_bits: Vec<f32> =
                (0..cout).map(|_| (1 + rng.below(16)) as f32).collect();
            (n, g, wb, ab, cbk, grouped, relu, x, wt, b, ch_bits)
        },
        |(n, g, wb, ab, cbk, grouped, relu, x, wt, b, ch_bits)| {
            let layer = if *grouped {
                IntConv2d::new_grouped_cbk("cg", wt, *g, b, ch_bits, *ab, *relu, *cbk)
            } else {
                IntConv2d::new_cbk("c", wt, *g, b, *wb, *ab, *relu, *cbk)
            }
            .map_err(|e| e.to_string())?;
            let fast = layer.forward(x, *n);
            let slow = layer.forward_ref(x, *n);
            for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
                if f.to_bits() != s.to_bits() {
                    return Err(format!(
                        "{cbk:?} grouped={grouped} {g:?} bits ({wb},{ab}) \
                         elem {i}: {f} vs {s}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn uniform_codebook_constructors_are_identity() {
    // Routing a uniform build through the codebook constructors must
    // change nothing: same packed bytes, no shift plan, bit-identical
    // forwards — the byte/bit-compat half of the acceptance criterion.
    check(
        "fastpath-uniform-cbk-identity",
        48,
        |rng| {
            let n = 1 + rng.below_usize(6);
            let din = 1 + rng.below_usize(32);
            let dout = 1 + rng.below_usize(24);
            let wb = 1 + rng.below(16) as u32;
            let ab = 1 + rng.below(16) as u32;
            let x = rand_vec(rng, n * din);
            let w = rand_vec(rng, din * dout);
            let b = rand_vec(rng, dout);
            (n, din, dout, wb, ab, x, w, b)
        },
        |(n, din, dout, wb, ab, x, w, b)| {
            let plain = IntDense::new("p", w, *din, *dout, b, *wb, *ab, true)
                .map_err(|e| e.to_string())?;
            let uni = IntDense::new_cbk(
                "p", w, *din, *dout, b, *wb, *ab, true,
                quant::Codebook::Uniform,
            )
            .map_err(|e| e.to_string())?;
            if uni.uses_shift_gemm() {
                return Err("uniform codebook must not build a shift plan".into());
            }
            if plain.packed_per_layer().map(|p| &p.data)
                != uni.packed_per_layer().map(|p| &p.data)
            {
                return Err("uniform codebook changed the packed bytes".into());
            }
            let a = plain.forward(x, *n);
            let c = uni.forward(x, *n);
            if a.iter().zip(&c).any(|(p, q)| p.to_bits() != q.to_bits()) {
                return Err("uniform codebook changed the forward".into());
            }
            Ok(())
        },
    );
}

#[test]
fn blocked_gemm_matches_scalar_ref() {
    check(
        "fastpath-gemm-parity",
        48,
        |rng| {
            let n = 1 + rng.below_usize(12);
            let din = 1 + rng.below_usize(48);
            let dout = 1 + rng.below_usize(40);
            let wb = 1 + rng.below(16) as u32;
            let ab = 1 + rng.below(16) as u32;
            let relu = rng.below(2) == 0;
            let x = rand_vec(rng, n * din);
            let w = rand_vec(rng, din * dout);
            let b = rand_vec(rng, dout);
            (n, din, dout, wb, ab, relu, x, w, b)
        },
        |(n, din, dout, wb, ab, relu, x, w, b)| {
            let layer = IntDense::new("p", w, *din, *dout, b, *wb, *ab, *relu)
                .map_err(|e| e.to_string())?;
            let fast = layer.forward(x, *n);
            let slow = layer.forward_ref(x, *n);
            for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
                if f.to_bits() != s.to_bits() {
                    return Err(format!(
                        "({n},{din},{dout}) bits ({wb},{ab}) elem {i}: {f} vs {s}"
                    ));
                }
            }
            Ok(())
        },
    );
}
