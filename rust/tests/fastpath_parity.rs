//! Fast-path parity suite: the blocked i64 GEMM, the word-level
//! bitpacker and the QuantPlan kernel must be **bit-identical** to the
//! retained `*_ref` scalar implementations, across every bitlength and
//! at unaligned lengths.  Pure rust — runs without artifacts.

use bitprune::bitpack;
use bitprune::infer::IntDense;
use bitprune::quant;
use bitprune::util::proptest::check;
use bitprune::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, 1.5)).collect()
}

#[test]
fn pack_roundtrip_all_bitlengths_unaligned() {
    // pack -> unpack_codes -> repack reproduces the byte stream, for
    // every bitlength 1..=16 at lengths that straddle word boundaries.
    check(
        "fastpath-pack-roundtrip",
        256,
        |rng| {
            let bits = 1 + rng.below(16) as u32;
            let len = 1 + rng.below_usize(300);
            (rand_vec(rng, len), bits)
        },
        |(xs, bits)| {
            let p = bitpack::pack(xs, *bits).map_err(|e| e.to_string())?;
            let codes = bitpack::unpack_codes(&p);
            if codes.len() != xs.len() {
                return Err("length mismatch".into());
            }
            let max_code = (1u32 << bits) - 1;
            if codes.iter().any(|&c| c > max_code) {
                return Err(format!("code exceeds {max_code}"));
            }
            // Dequantized values survive a second quantize+pack exactly.
            let vals = bitpack::unpack(&p);
            let p2 = bitpack::pack(&vals, *bits).map_err(|e| e.to_string())?;
            if bitpack::unpack_codes(&p2).len() != codes.len() {
                return Err("repack length mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn word_packer_bitstream_matches_scalar_ref() {
    check(
        "fastpath-pack-parity",
        256,
        |rng| {
            let bits = 1 + rng.below(16) as u32;
            let len = 1 + rng.below_usize(300);
            (rand_vec(rng, len), bits)
        },
        |(xs, bits)| {
            let fast = bitpack::pack(xs, *bits).map_err(|e| e.to_string())?;
            let slow = bitpack::pack_ref(xs, *bits).map_err(|e| e.to_string())?;
            if fast != slow {
                return Err(format!("byte stream differs at {bits} bits"));
            }
            if bitpack::unpack_codes(&fast) != bitpack::unpack_codes_ref(&fast) {
                return Err("unpack_codes differs".into());
            }
            let (f, r) = (bitpack::unpack(&fast), bitpack::unpack_ref(&fast));
            if f.iter().zip(&r).any(|(a, b)| a.to_bits() != b.to_bits()) {
                return Err("unpack differs".into());
            }
            Ok(())
        },
    );
}

#[test]
fn quantplan_kernel_matches_scalar_ref() {
    check(
        "fastpath-quant-parity",
        256,
        |rng| {
            let len = 1 + rng.below_usize(300);
            // Half the cases integer bitlengths (alpha == 0 shortcut),
            // half fractional; scale varies over orders of magnitude.
            let n = if rng.below(2) == 0 {
                (1 + rng.below(16)) as f32
            } else {
                rng.range_f32(1.0, 16.0)
            };
            let scale = 10f32.powi(rng.below(5) as i32 - 2);
            let xs: Vec<f32> =
                (0..len).map(|_| rng.normal_f32(0.0, scale)).collect();
            (xs, n)
        },
        |(xs, n)| {
            let mut fast = xs.clone();
            quant::fake_quant_slice(&mut fast, *n);
            let mut slow = xs.clone();
            quant::fake_quant_slice_ref(&mut slow, *n);
            for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
                if f.to_bits() != s.to_bits() {
                    return Err(format!("elem {i}: {f} vs {s} (n={n})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn blocked_gemm_matches_scalar_ref() {
    check(
        "fastpath-gemm-parity",
        48,
        |rng| {
            let n = 1 + rng.below_usize(12);
            let din = 1 + rng.below_usize(48);
            let dout = 1 + rng.below_usize(40);
            let wb = 1 + rng.below(16) as u32;
            let ab = 1 + rng.below(16) as u32;
            let relu = rng.below(2) == 0;
            let x = rand_vec(rng, n * din);
            let w = rand_vec(rng, din * dout);
            let b = rand_vec(rng, dout);
            (n, din, dout, wb, ab, relu, x, w, b)
        },
        |(n, din, dout, wb, ab, relu, x, w, b)| {
            let layer = IntDense::new("p", w, *din, *dout, b, *wb, *ab, *relu)
                .map_err(|e| e.to_string())?;
            let fast = layer.forward(x, *n);
            let slow = layer.forward_ref(x, *n);
            for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
                if f.to_bits() != s.to_bits() {
                    return Err(format!(
                        "({n},{din},{dout}) bits ({wb},{ab}) elem {i}: {f} vs {s}"
                    ));
                }
            }
            Ok(())
        },
    );
}
