//! BPMA artifact robustness suite.
//!
//! Two halves:
//!
//! 1. **Roundtrip property** — for random geometries, bitlengths and
//!    seeds: freeze → serialize → parse → instantiate produces a net
//!    whose logits are **bit-identical** to the source net on random
//!    batches (the deploy contract: a `.bpma` file on disk *is* the
//!    model, with no dataset or trainer involved).
//! 2. **Corrupt-input robustness** — truncation at *every* byte
//!    boundary (which covers every section boundary), a flipped byte
//!    in every section payload, bad magic/version, and hostile
//!    length/count fields must all fail with a clean `Err`: no panic,
//!    no OOM-scale allocation.  Pure rust — runs without AOT artifacts.

use bitprune::deploy::{freeze, section_table, Artifact};
use bitprune::quant::Codebook;
use bitprune::serve::{
    synthetic_conv_net, synthetic_conv_net_cbk, synthetic_conv_net_grouped,
    synthetic_net, synthetic_net_cbk, synthetic_net_grouped,
};
use bitprune::util::proptest::check;
use bitprune::util::rng::Rng;

fn rand_batch(rng: &mut Rng, n: usize, din: usize) -> Vec<f32> {
    (0..n * din).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

#[test]
fn roundtrip_instantiate_is_bit_identical_property() {
    check(
        "bpma-roundtrip",
        24,
        |rng: &mut Rng| {
            // Random small geometry: 1-3 layers, odd dims, random bits.
            let n_layers = 1 + rng.below_usize(3);
            let mut dims = vec![1 + rng.below_usize(24)];
            for _ in 0..n_layers {
                dims.push(1 + rng.below_usize(24));
            }
            let w_bits = 1 + rng.below(8) as u32;
            let a_bits = 1 + rng.below(8) as u32;
            let seed = rng.below(1 << 30);
            let batch = 1 + rng.below_usize(9);
            (dims, w_bits, a_bits, seed, batch)
        },
        |(dims, w_bits, a_bits, seed, batch)| {
            let net = synthetic_net(dims, *seed, *w_bits, *a_bits);
            let art = freeze(&net, "prop");
            let bytes = art.to_bytes();
            let rebuilt = Artifact::from_bytes(&bytes)
                .map_err(|e| format!("parse: {e:#}"))?
                .instantiate()
                .map_err(|e| format!("instantiate: {e:#}"))?;
            let mut rng = Rng::new(seed.wrapping_add(0x9E37));
            let x = rand_batch(&mut rng, *batch, dims[0]);
            let want = net.forward(&x, *batch);
            let got = rebuilt.forward(&x, *batch);
            if want.len() != got.len() {
                return Err("logits length mismatch".into());
            }
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("logit {i}: source {a} vs instantiated {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn save_load_file_roundtrip() {
    let dir = std::env::temp_dir().join("bitprune-deploy-tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("roundtrip.bpma");

    let net = synthetic_net(&[10, 18, 4], 0xD15C, 3, 5);
    let art = freeze(&net, "disk");
    art.save(&path).unwrap();
    let loaded = Artifact::load(&path).unwrap();
    assert_eq!(loaded.model, "disk");
    let rebuilt = loaded.instantiate().unwrap();
    let mut rng = Rng::new(1);
    let x = rand_batch(&mut rng, 6, 10);
    let want = net.forward(&x, 6);
    let got = rebuilt.forward(&x, 6);
    assert!(want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()));
    // A missing file is a clean error.
    assert!(Artifact::load(dir.join("nope.bpma")).is_err());
}

#[test]
fn grouped_roundtrip_instantiate_is_bit_identical_property() {
    // The GRP0 contract: a mixed-per-channel-bit net roundtrips
    // export → parse → instantiate() bit-identically.
    check(
        "bpma-grouped-roundtrip",
        16,
        |rng: &mut Rng| {
            let n_layers = 1 + rng.below_usize(3);
            let mut dims = vec![1 + rng.below_usize(20)];
            for _ in 0..n_layers {
                dims.push(1 + rng.below_usize(20));
            }
            let a_bits = 1 + rng.below(8) as u32;
            let seed = rng.below(1 << 30);
            let batch = 1 + rng.below_usize(7);
            (dims, a_bits, seed, batch)
        },
        |(dims, a_bits, seed, batch)| {
            let net = synthetic_net_grouped(dims, *seed, &[2, 4, 8, 3], *a_bits);
            let art = freeze(&net, "grouped-prop");
            if !art.is_grouped() {
                return Err("fixture is not grouped".into());
            }
            let bytes = art.to_bytes();
            let rebuilt = Artifact::from_bytes(&bytes)
                .map_err(|e| format!("parse: {e:#}"))?
                .instantiate()
                .map_err(|e| format!("instantiate: {e:#}"))?;
            let mut rng = Rng::new(seed.wrapping_add(0x6666));
            let x = rand_batch(&mut rng, *batch, dims[0]);
            let want = net.forward(&x, *batch);
            let got = rebuilt.forward(&x, *batch);
            if want.len() != got.len() {
                return Err("logits length mismatch".into());
            }
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("logit {i}: source {a} vs instantiated {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn grouped_artifact_has_grp0_and_per_layer_does_not() {
    // Per-layer artifacts must stay byte-compatible with pre-GRP0
    // writers (exactly the four v1 sections); grouped artifacts append
    // a checksummed, known GRP0.
    let flat = freeze(&synthetic_net(&[6, 9, 4], 1, 4, 4), "flat");
    let tags: Vec<String> = section_table(&flat.to_bytes())
        .unwrap()
        .iter()
        .map(|s| s.tag.clone())
        .collect();
    assert_eq!(tags, ["MET0", "LAY0", "WCT0", "BIA0"]);

    let grouped = freeze(&synthetic_net_grouped(&[6, 9, 4], 1, &[2, 4, 8], 4), "grp");
    let table = section_table(&grouped.to_bytes()).unwrap();
    let tags: Vec<&str> = table.iter().map(|s| s.tag.as_str()).collect();
    assert_eq!(tags, ["MET0", "LAY0", "WCT0", "BIA0", "GRP0"]);
    assert!(table.iter().all(|s| s.crc_ok && s.known));
}

#[test]
fn grouped_truncation_and_corruption_fuzz() {
    // Truncation at every byte and a flipped byte in every section
    // (GRP0 included) must fail cleanly for a grouped artifact too.
    let art = freeze(&synthetic_net_grouped(&[5, 7, 3], 0x6B, &[2, 5], 3), "gfuzz");
    let bytes = art.to_bytes();
    assert!(Artifact::from_bytes(&bytes).is_ok());
    for cut in 0..bytes.len() {
        assert!(
            Artifact::from_bytes(&bytes[..cut]).is_err(),
            "grouped prefix of {cut}/{} bytes parsed successfully",
            bytes.len()
        );
    }
    for s in &section_table(&bytes).unwrap() {
        for probe in [0, s.payload_len / 2, s.payload_len.saturating_sub(1)] {
            let mut corrupt = bytes.clone();
            corrupt[s.payload_offset + probe] ^= 0x20;
            assert!(
                Artifact::from_bytes(&corrupt).is_err(),
                "flipping byte {probe} of grouped section {} went unnoticed",
                s.tag
            );
        }
    }
}

#[test]
fn grouped_flag_without_grp0_is_rejected() {
    // Splice the GRP0 section out of a grouped artifact: the LAY0
    // grouped flags survive, so the loader must refuse loudly instead
    // of mis-decoding the channel-aligned WCT0 payload per-layer.
    let art = freeze(&synthetic_net_grouped(&[4, 6, 2], 5, &[2, 4], 3), "nogrp");
    let bytes = art.to_bytes();
    let table = section_table(&bytes).unwrap();
    let grp = table.iter().find(|s| s.tag == "GRP0").unwrap();
    // A section frame is tag(4) + len(8) + payload + crc(4).
    let frame_start = grp.payload_offset - 12;
    let frame_end = grp.payload_offset + grp.payload_len + 4;
    let mut spliced = Vec::new();
    spliced.extend_from_slice(&bytes[..frame_start]);
    spliced.extend_from_slice(&bytes[frame_end..]);
    // Fix the section count (offset 12).
    let count = u32::from_le_bytes(spliced[12..16].try_into().unwrap());
    spliced[12..16].copy_from_slice(&(count - 1).to_le_bytes());
    let err = Artifact::from_bytes(&spliced).unwrap_err();
    assert!(format!("{err:#}").contains("GRP0"), "{err:#}");
}

#[test]
fn conv_roundtrip_instantiate_is_bit_identical() {
    // The CNV0 contract: conv artifacts (per-layer and per-kernel)
    // roundtrip freeze → bytes → parse → instantiate() bit-identically,
    // and the wire image carries a checksummed, known CNV0 section
    // (after GRP0 for grouped models).
    for (net, name, want_tags) in [
        (
            synthetic_conv_net(0xC0417, 4, 5),
            "conv-flat",
            vec!["MET0", "LAY0", "WCT0", "BIA0", "CNV0"],
        ),
        (
            synthetic_conv_net_grouped(0xC0418, &[2, 4, 8], 5),
            "conv-grouped",
            vec!["MET0", "LAY0", "WCT0", "BIA0", "GRP0", "CNV0"],
        ),
    ] {
        let art = freeze(&net, name);
        assert!(art.is_conv(), "{name}: conv fixture must freeze as conv");
        let bytes = art.to_bytes();
        let table = section_table(&bytes).unwrap();
        let tags: Vec<&str> = table.iter().map(|s| s.tag.as_str()).collect();
        assert_eq!(tags, want_tags, "{name}");
        assert!(table.iter().all(|s| s.crc_ok && s.known), "{name}");

        let rebuilt = Artifact::from_bytes(&bytes).unwrap().instantiate().unwrap();
        let mut rng = Rng::new(0xF00D);
        let x = rand_batch(&mut rng, 5, net.in_features());
        let want = net.forward(&x, 5);
        let got = rebuilt.forward(&x, 5);
        assert_eq!(want.len(), got.len(), "{name}");
        assert!(
            want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{name}: instantiated conv net diverges from source"
        );
    }
}

#[test]
fn conv_truncation_and_corruption_fuzz() {
    // Truncation at every byte and a flipped byte in every section
    // (CNV0 included) must fail cleanly for a conv artifact too.
    let art = freeze(&synthetic_conv_net(0xC0FF, 3, 4), "cfuzz");
    let bytes = art.to_bytes();
    assert!(Artifact::from_bytes(&bytes).is_ok());
    for cut in 0..bytes.len() {
        assert!(
            Artifact::from_bytes(&bytes[..cut]).is_err(),
            "conv prefix of {cut}/{} bytes parsed successfully",
            bytes.len()
        );
    }
    for s in &section_table(&bytes).unwrap() {
        for probe in [0, s.payload_len / 2, s.payload_len.saturating_sub(1)] {
            let mut corrupt = bytes.clone();
            corrupt[s.payload_offset + probe] ^= 0x20;
            assert!(
                Artifact::from_bytes(&corrupt).is_err(),
                "flipping byte {probe} of conv section {} went unnoticed",
                s.tag
            );
        }
    }
}

#[test]
fn conv_flag_without_cnv0_is_rejected() {
    // Splice the CNV0 section out of a conv artifact: the LAY0 conv
    // flags (and poisoned din=0 fields) survive, so the loader must
    // refuse loudly — a pre-CNV0 reader must never quietly build a
    // degenerate dense net from a conv artifact.
    let art = freeze(&synthetic_conv_net(0xC0DE, 4, 4), "nocnv");
    let bytes = art.to_bytes();
    let table = section_table(&bytes).unwrap();
    let cnv = table.iter().find(|s| s.tag == "CNV0").unwrap();
    // A section frame is tag(4) + len(8) + payload + crc(4).
    let frame_start = cnv.payload_offset - 12;
    let frame_end = cnv.payload_offset + cnv.payload_len + 4;
    let mut spliced = Vec::new();
    spliced.extend_from_slice(&bytes[..frame_start]);
    spliced.extend_from_slice(&bytes[frame_end..]);
    // Fix the section count (offset 12).
    let count = u32::from_le_bytes(spliced[12..16].try_into().unwrap());
    spliced[12..16].copy_from_slice(&(count - 1).to_le_bytes());
    let err = Artifact::from_bytes(&spliced).unwrap_err();
    assert!(format!("{err:#}").contains("CNV0"), "{err:#}");
}

#[test]
fn codebook_roundtrip_instantiate_is_bit_identical() {
    // The CBK0 contract: codebook artifacts (dense mixed-granularity
    // and conv per-layer) roundtrip freeze → bytes → parse →
    // instantiate() bit-identically, with a checksummed, known CBK0
    // section in the expected position.
    for cbk in [Codebook::PowerOfTwo, Codebook::AdditivePot2] {
        for (net, name, want_tags) in [
            (
                synthetic_net_cbk(&[7, 12, 10, 4], 0xCB41, 3, 5, cbk),
                "cbk-dense",
                vec!["MET0", "LAY0", "WCT0", "BIA0", "GRP0", "CBK0"],
            ),
            (
                synthetic_conv_net_cbk(0xCB42, 4, 5, cbk),
                "cbk-conv",
                vec!["MET0", "LAY0", "WCT0", "BIA0", "CNV0", "CBK0"],
            ),
        ] {
            let art = freeze(&net, name);
            assert!(art.has_codebook(), "{name}: fixture must carry a codebook");
            let bytes = art.to_bytes();
            let table = section_table(&bytes).unwrap();
            let tags: Vec<&str> = table.iter().map(|s| s.tag.as_str()).collect();
            assert_eq!(tags, want_tags, "{name}");
            assert!(table.iter().all(|s| s.crc_ok && s.known), "{name}");

            let parsed = Artifact::from_bytes(&bytes).unwrap();
            assert!(parsed.layers.iter().all(|l| l.codebook() == cbk), "{name}");
            let rebuilt = parsed.instantiate().unwrap();
            assert!(rebuilt.layers.iter().all(|l| l.codebook() == cbk), "{name}");
            let mut rng = Rng::new(0xF00E);
            let x = rand_batch(&mut rng, 5, net.in_features());
            let want = net.forward(&x, 5);
            let got = rebuilt.forward(&x, 5);
            assert_eq!(want.len(), got.len(), "{name}");
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{name} ({cbk:?}): instantiated codebook net diverges from source"
            );
        }
    }
}

#[test]
fn codebook_truncation_and_corruption_fuzz() {
    // Truncation at every byte and a flipped byte in every section
    // (CBK0 included) must fail cleanly for a codebook artifact too.
    let art = freeze(
        &synthetic_net_cbk(&[5, 7, 6, 3], 0xCBF, 3, 4, Codebook::AdditivePot2),
        "kfuzz",
    );
    let bytes = art.to_bytes();
    assert!(Artifact::from_bytes(&bytes).is_ok());
    for cut in 0..bytes.len() {
        assert!(
            Artifact::from_bytes(&bytes[..cut]).is_err(),
            "codebook prefix of {cut}/{} bytes parsed successfully",
            bytes.len()
        );
    }
    for s in &section_table(&bytes).unwrap() {
        for probe in [0, s.payload_len / 2, s.payload_len.saturating_sub(1)] {
            let mut corrupt = bytes.clone();
            corrupt[s.payload_offset + probe] ^= 0x20;
            assert!(
                Artifact::from_bytes(&corrupt).is_err(),
                "flipping byte {probe} of codebook section {} went unnoticed",
                s.tag
            );
        }
    }
}

#[test]
fn codebook_flag_without_cbk0_is_rejected() {
    // Splice the CBK0 section out of a codebook artifact: the LAY0
    // codebook flags (and poisoned bits fields) survive, so the loader
    // must refuse loudly — a reader must never decode (sign, exponent)
    // shift fields as uniform grid codes.
    let art = freeze(
        &synthetic_net_cbk(&[4, 6, 8, 2], 0xCB5, 4, 3, Codebook::PowerOfTwo),
        "nocbk",
    );
    let bytes = art.to_bytes();
    let table = section_table(&bytes).unwrap();
    let cbk = table.iter().find(|s| s.tag == "CBK0").unwrap();
    // A section frame is tag(4) + len(8) + payload + crc(4).
    let frame_start = cbk.payload_offset - 12;
    let frame_end = cbk.payload_offset + cbk.payload_len + 4;
    let mut spliced = Vec::new();
    spliced.extend_from_slice(&bytes[..frame_start]);
    spliced.extend_from_slice(&bytes[frame_end..]);
    // Fix the section count (offset 12).
    let count = u32::from_le_bytes(spliced[12..16].try_into().unwrap());
    spliced[12..16].copy_from_slice(&(count - 1).to_le_bytes());
    let err = Artifact::from_bytes(&spliced).unwrap_err();
    assert!(format!("{err:#}").contains("CBK0"), "{err:#}");
}

#[test]
fn truncation_at_every_byte_is_a_clean_error() {
    // Every strict prefix of a valid artifact must fail to parse —
    // this sweeps every section boundary, every length field and every
    // payload interior.  (A tiny net keeps the byte count manageable;
    // each attempt must fail fast.)
    let art = freeze(&synthetic_net(&[5, 7, 3], 0x7777, 2, 3), "trunc");
    let bytes = art.to_bytes();
    assert!(Artifact::from_bytes(&bytes).is_ok());
    for cut in 0..bytes.len() {
        assert!(
            Artifact::from_bytes(&bytes[..cut]).is_err(),
            "prefix of {cut}/{} bytes parsed successfully",
            bytes.len()
        );
    }
}

#[test]
fn flipped_byte_in_every_section_payload_fails_crc() {
    let art = freeze(&synthetic_net(&[6, 9, 2], 0xC4C, 4, 4), "crc");
    let bytes = art.to_bytes();
    let sections = section_table(&bytes).unwrap();
    assert_eq!(sections.len(), 4, "v1 writes four sections");
    for s in &sections {
        assert!(s.crc_ok && s.known);
        // Flip one byte at the start, middle and end of the payload.
        for probe in [0, s.payload_len / 2, s.payload_len.saturating_sub(1)] {
            let mut corrupt = bytes.clone();
            corrupt[s.payload_offset + probe] ^= 0x10;
            let err = Artifact::from_bytes(&corrupt);
            assert!(
                err.is_err(),
                "flipping byte {probe} of section {} went unnoticed",
                s.tag
            );
            // The section table itself reports the damage.
            let table = section_table(&corrupt).unwrap();
            assert!(
                table.iter().any(|t| !t.crc_ok),
                "section table missed the corrupt {} section",
                s.tag
            );
        }
    }
}

#[test]
fn flipped_crc_byte_itself_is_detected() {
    // Corrupting the stored checksum (rather than the payload) must
    // also fail: stored != computed either way.
    let art = freeze(&synthetic_net(&[4, 6, 2], 1, 3, 3), "crcfield");
    let bytes = art.to_bytes();
    let sections = section_table(&bytes).unwrap();
    for s in &sections {
        let crc_off = s.payload_offset + s.payload_len; // crc follows payload
        let mut corrupt = bytes.clone();
        corrupt[crc_off] ^= 0x01;
        assert!(
            Artifact::from_bytes(&corrupt).is_err(),
            "corrupt stored crc of {} accepted",
            s.tag
        );
    }
}

#[test]
fn bad_magic_and_version_rejected() {
    let art = freeze(&synthetic_net(&[4, 5, 2], 2, 4, 4), "hdr");
    let good = art.to_bytes();

    let mut bad_magic = good.clone();
    bad_magic[..4].copy_from_slice(b"BPCK"); // checkpoint magic != artifact
    let err = Artifact::from_bytes(&bad_magic).unwrap_err();
    assert!(format!("{err:#}").contains("magic"), "{err:#}");

    let mut bad_version = good.clone();
    bad_version[4..8].copy_from_slice(&2u32.to_le_bytes());
    let err = Artifact::from_bytes(&bad_version).unwrap_err();
    assert!(format!("{err:#}").contains("version"), "{err:#}");
}

#[test]
fn hostile_lengths_fail_without_oom_scale_allocation() {
    let art = freeze(&synthetic_net(&[4, 5, 2], 3, 4, 4), "hostile");
    let good = art.to_bytes();

    // Section length field claiming u64::MAX: the first section's
    // length lives right after the 16-byte header + 4-byte tag.
    let mut huge_len = good.clone();
    huge_len[20..28].copy_from_slice(&u64::MAX.to_le_bytes());
    assert!(Artifact::from_bytes(&huge_len).is_err());

    // Section count claiming u32::MAX (offset 12): parsing must fail
    // on the first absent section, not pre-allocate anything.
    let mut huge_count = good.clone();
    huge_count[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(Artifact::from_bytes(&huge_count).is_err());

    // A hand-built artifact whose MET0 section claims 2^31 layers:
    // the LAY0 walk must hit end-of-section and error, allocating
    // nothing proportional to the claim.  Rebuild the MET0 payload
    // with a hostile layer count but valid checksums.
    let sections = section_table(&good).unwrap();
    let met = sections.iter().find(|s| s.tag == "MET0").unwrap();
    let mut hostile = good.clone();
    // MET0 payload layout: str_u32 model | num_classes u32 | n_layers u32.
    let n_layers_off = met.payload_offset + met.payload_len - 4;
    hostile[n_layers_off..n_layers_off + 4]
        .copy_from_slice(&0x8000_0000u32.to_le_bytes());
    // Fix up the checksum so only the count is hostile.
    let payload =
        hostile[met.payload_offset..met.payload_offset + met.payload_len].to_vec();
    let crc = bitprune::util::binio::crc32(&payload);
    let crc_off = met.payload_offset + met.payload_len;
    hostile[crc_off..crc_off + 4].copy_from_slice(&crc.to_le_bytes());
    let err = Artifact::from_bytes(&hostile).unwrap_err();
    assert!(format!("{err:#}").contains("truncated"), "{err:#}");
}

#[test]
fn hostile_activation_ranges_rejected() {
    // NaN / infinite / inverted calibrated ranges would load silently
    // and quantize every activation to code 0 — the loader must refuse
    // them like it refuses bad weight-plan headers.
    for (lo, hi) in [
        (f32::NAN, 1.0f32),
        (0.0, f32::INFINITY),
        (2.0, -2.0), // inverted
    ] {
        let mut art = freeze(&synthetic_net(&[4, 5, 2], 9, 4, 4), "range");
        art.layers[0].act_range = Some((lo, hi));
        let err = Artifact::from_bytes(&art.to_bytes()).unwrap_err();
        assert!(
            format!("{err:#}").contains("activation range"),
            "({lo}, {hi}): {err:#}"
        );
    }
    // A degenerate-but-finite range (lo == hi) stays legal: the
    // quantizer's epsilon guard handles it.
    let mut art = freeze(&synthetic_net(&[4, 5, 2], 9, 4, 4), "range");
    art.layers[0].act_range = Some((0.5, 0.5));
    assert!(Artifact::from_bytes(&art.to_bytes()).is_ok());
}

#[test]
fn non_finite_biases_rejected() {
    // Bias floats are the remaining per-layer payload: NaN/Inf there
    // would serve NaN logits silently, so the loader refuses them like
    // it refuses bad quant headers and ranges.
    for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
        let mut art = freeze(&synthetic_net(&[4, 5, 2], 10, 4, 4), "bias");
        art.layers[1].bias[0] = bad;
        let err = Artifact::from_bytes(&art.to_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("bias"), "{bad}: {err:#}");
    }
}

#[test]
fn cross_section_consistency_is_enforced() {
    // Declare 3 classes in MET0 while the last layer emits 2: the
    // sections are individually valid, the combination is not.
    let art = freeze(&synthetic_net(&[4, 5, 2], 4, 4, 4), "xsec");
    let good = art.to_bytes();
    let sections = section_table(&good).unwrap();
    let met = sections.iter().find(|s| s.tag == "MET0").unwrap();
    let mut bad = good.clone();
    // num_classes sits 8 bytes before the end of MET0 (…| classes u32 | layers u32).
    let classes_off = met.payload_offset + met.payload_len - 8;
    bad[classes_off..classes_off + 4].copy_from_slice(&3u32.to_le_bytes());
    let payload = bad[met.payload_offset..met.payload_offset + met.payload_len].to_vec();
    let crc = bitprune::util::binio::crc32(&payload);
    let crc_off = met.payload_offset + met.payload_len;
    bad[crc_off..crc_off + 4].copy_from_slice(&crc.to_le_bytes());
    let err = Artifact::from_bytes(&bad).unwrap_err();
    assert!(format!("{err:#}").contains("classes"), "{err:#}");
}
