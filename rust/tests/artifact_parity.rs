//! L1↔L3 parity: the rust quantizer mirror must match the compiled
//! Pallas artifacts bit-for-bit (within f32 round-off), proving that the
//! coordinator's selection/accounting math operates on the same numbers
//! the compiled models see.

mod common;

use bitprune::quant;
use bitprune::runtime::Runtime;
use bitprune::tensor::HostTensor;
use bitprune::util::rng::Rng;

#[test]
fn fake_quant_artifact_matches_rust_mirror() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let exe = rt.load("fake_quant").unwrap();
    let mut rng = Rng::new(0xFEED);

    for case in 0..8 {
        // Cover fractional, integer, clipped-low and clipped-high bits.
        let n = match case {
            0 => 1.0,
            1 => 0.25,  // clips to 1
            2 => 8.0,
            3 => 16.0,
            _ => rng.range_f32(1.0, 12.0),
        };
        let scale = 10f32.powi(rng.below(5) as i32 - 2);
        let xs: Vec<f32> =
            (0..4096).map(|_| rng.normal_f32(0.0, scale)).collect();
        let out = exe
            .run(&[
                HostTensor::f32(&[4096], xs.clone()).unwrap(),
                HostTensor::scalar_f32(n),
            ])
            .unwrap();
        let got = out[0].as_f32().unwrap();
        let mut want = xs.clone();
        quant::fake_quant_slice(&mut want, n);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 1e-5 * scale.max(1.0),
                "case {case} elem {i}: artifact {g} vs rust {w} (n={n})"
            );
        }
    }
}

#[test]
fn quant_matmul_artifact_matches_composition() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let exe = rt.load("quant_matmul").unwrap();
    let mut rng = Rng::new(0xBEEF);

    let a: Vec<f32> = (0..64 * 128).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let w: Vec<f32> = (0..128 * 96).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let (na, nw) = (4.3f32, 3.1f32);

    let out = exe
        .run(&[
            HostTensor::f32(&[64, 128], a.clone()).unwrap(),
            HostTensor::f32(&[128, 96], w.clone()).unwrap(),
            HostTensor::scalar_f32(na),
            HostTensor::scalar_f32(nw),
        ])
        .unwrap();
    let got = out[0].as_f32().unwrap();

    // Rust composition: quantize both operands, naive matmul.
    let mut aq = a.clone();
    quant::fake_quant_slice(&mut aq, na);
    let mut wq = w.clone();
    quant::fake_quant_slice(&mut wq, nw);
    for i in 0..64 {
        for j in 0..96 {
            let mut acc = 0.0f64;
            for k in 0..128 {
                acc += aq[i * 128 + k] as f64 * wq[k * 96 + j] as f64;
            }
            let g = got[i * 96 + j] as f64;
            assert!(
                (g - acc).abs() < 1e-3 * (1.0 + acc.abs()),
                "({i},{j}): artifact {g} vs rust {acc}"
            );
        }
    }
}

#[test]
fn init_artifact_is_seed_deterministic() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let exe = rt.load("mlp_init").unwrap();
    let a = exe.run(&[HostTensor::scalar_u32(7)]).unwrap();
    let b = exe.run(&[HostTensor::scalar_u32(7)]).unwrap();
    let c = exe.run(&[HostTensor::scalar_u32(8)]).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x, y, "same seed must give identical params");
    }
    assert!(
        a.iter().zip(&c).any(|(x, y)| x != y),
        "different seeds must differ"
    );
}

#[test]
fn artifact_listing_contains_models() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let names = rt.list_artifacts().unwrap();
    for required in ["fake_quant", "mlp_train", "mlp_eval", "mlp_init"] {
        assert!(
            names.iter().any(|n| n == required),
            "missing artifact '{required}' in {names:?}"
        );
    }
}

#[test]
fn runtime_rejects_missing_artifact() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    assert!(rt.load("no_such_artifact").is_err());
}

#[test]
fn executable_stats_track_executions() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu(&dir).unwrap();
    let exe = rt.load("fake_quant").unwrap();
    let before = exe.stats().executions;
    let xs = HostTensor::f32(&[4096], vec![0.5; 4096]).unwrap();
    exe.run(&[xs, HostTensor::scalar_f32(4.0)]).unwrap();
    let stats = exe.stats();
    assert_eq!(stats.executions, before + 1);
    assert!(stats.total_exec_nanos > 0);
    assert!(stats.compile_nanos > 0);
}
