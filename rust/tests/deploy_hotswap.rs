//! Hot-swap correctness under concurrent traffic.
//!
//! The registry/serving contract this suite pins:
//!
//! * a `ModelRegistry::publish` during sustained concurrent
//!   `ServerHandle::infer` traffic **never drops or rejects** a
//!   request;
//! * every response is **exactly** one model version's answer — bit
//!   for bit, with a version tag that matches the logits (no torn
//!   batches, no half-swapped model, no mixing);
//! * after the swap drains, responses come from the new version only;
//! * rollback restores the old version for subsequent requests.
//!
//! Pure rust, synthetic fixtures — runs without AOT artifacts.

use std::sync::Arc;
use std::time::Duration;

use bitprune::deploy::ModelRegistry;
use bitprune::infer::IntNet;
use bitprune::serve::{synthetic_net, ServeConfig, Server};
use bitprune::util::rng::Rng;

const DIMS: &[usize] = &[10, 22, 4];

fn fixture(seed: u64) -> Arc<IntNet> {
    Arc::new(synthetic_net(DIMS, seed, 4, 5))
}

/// Bitwise row equality.
fn same(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn swap_under_concurrent_traffic_never_drops_or_mixes() {
    let net_a = fixture(0xA);
    let net_b = fixture(0xB);

    // Fixed per-client sample sets, with solo-forward expectations
    // under both versions computed up front.
    let clients = 4usize;
    let per_client = 60usize;
    let mut rng = Rng::new(0x5AB);
    let samples: Vec<Vec<Vec<f32>>> = (0..clients)
        .map(|_| {
            (0..per_client)
                .map(|_| (0..DIMS[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                .collect()
        })
        .collect();
    // The two versions must actually disagree somewhere, or "matches
    // exactly one version" would be vacuous.
    let probe = &samples[0][0];
    assert!(
        !same(&net_a.forward(probe, 1), &net_b.forward(probe, 1)),
        "fixture nets must produce different logits"
    );

    let registry = Arc::new(ModelRegistry::new(Arc::clone(&net_a), "a").unwrap());
    let server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig {
            threads: 2,
            max_batch: 8,
            batch_window: Duration::from_micros(300),
            max_queue: 4096,
        },
    )
    .unwrap();

    let total = clients * per_client;
    // Deterministic mid-traffic swap: every client rendezvous at the
    // one-third mark, the swapper publishes while they hold, a second
    // rendezvous releases them — so both versions are guaranteed to
    // serve real traffic regardless of scheduling, with no flaky
    // served-count race.
    let gate_at = per_client / 3;
    let before_swap = std::sync::Barrier::new(clients + 1);
    let after_swap = std::sync::Barrier::new(clients + 1);
    // (client, sample index, version tag, logits) for every response.
    let mut responses: Vec<(usize, usize, u64, Vec<f32>)> = Vec::with_capacity(total);
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for (c, my_samples) in samples.iter().enumerate() {
            let handle = server.handle();
            let (before_swap, after_swap) = (&before_swap, &after_swap);
            joins.push(scope.spawn(move || {
                let mut out = Vec::with_capacity(my_samples.len());
                for (i, x) in my_samples.iter().enumerate() {
                    if i == gate_at {
                        before_swap.wait();
                        after_swap.wait();
                    }
                    // Any Err here is a dropped/rejected request — the
                    // thing the swap must never cause.
                    let (version, logits) = handle
                        .infer_versioned(x.clone())
                        .expect("request rejected during hot-swap");
                    out.push((c, i, version, logits));
                }
                out
            }));
        }
        before_swap.wait();
        registry.publish(Arc::clone(&net_b), "b").unwrap();
        after_swap.wait();
        for j in joins {
            responses.extend(j.join().expect("client thread panicked"));
        }
    });
    assert_eq!(responses.len(), total, "every request must be answered");

    // Every response matches exactly one version's solo forward, and
    // its version tag agrees with which one.
    let mut v1 = 0usize;
    let mut v2 = 0usize;
    for (c, i, version, logits) in &responses {
        let x = &samples[*c][*i];
        let want_a = net_a.forward(x, 1);
        let want_b = net_b.forward(x, 1);
        let is_a = same(logits, &want_a);
        let is_b = same(logits, &want_b);
        match version {
            1 => {
                assert!(
                    is_a,
                    "client {c} sample {i}: tagged v1 but logits are not net A's"
                );
                v1 += 1;
            }
            2 => {
                assert!(
                    is_b,
                    "client {c} sample {i}: tagged v2 but logits are not net B's"
                );
                v2 += 1;
            }
            v => panic!("client {c} sample {i}: impossible version {v}"),
        }
        assert!(
            is_a || is_b,
            "client {c} sample {i}: logits match neither version"
        );
    }
    assert_eq!(v1 + v2, total);
    // The barrier makes the split exact: everything before the gate is
    // v1, everything after is v2.
    assert_eq!(v1, clients * gate_at, "pre-swap responses must all be v1");
    assert_eq!(v2, total - clients * gate_at, "post-swap responses must all be v2");

    // Post-drain: fresh requests are served by the new version only.
    let handle = server.handle();
    for x in samples[0].iter().take(5) {
        let (version, logits) = handle.infer_versioned(x.clone()).unwrap();
        assert_eq!(version, 2, "post-drain response served by the old version");
        assert!(same(&logits, &net_b.forward(x, 1)));
    }

    // Rollback: subsequent requests revert to version 1 / net A.
    registry.rollback(1).unwrap();
    let x = &samples[1][0];
    let (version, logits) = handle.infer_versioned(x.clone()).unwrap();
    assert_eq!(version, 1);
    assert!(same(&logits, &net_a.forward(x, 1)));

    let stats = server.shutdown();
    assert_eq!(stats.requests as usize, total + 5 + 1);
    assert!(stats.swaps >= 2, "publish + rollback both crossed the batcher");
}

#[test]
fn repeated_swaps_stay_consistent() {
    // A/B/A/B… every few batches: the version tag must always agree
    // with the logits, across many transitions.
    let net_a = fixture(0x11);
    let net_b = fixture(0x22);
    let registry = Arc::new(ModelRegistry::new(Arc::clone(&net_a), "a").unwrap());
    let server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig {
            threads: 1,
            max_batch: 4,
            batch_window: Duration::from_micros(200),
            max_queue: 1024,
        },
    )
    .unwrap();
    let handle = server.handle();
    let mut rng = Rng::new(0xAB);
    let mut published = vec![(1u64, Arc::clone(&net_a))];
    for round in 0..6 {
        let (net, label): (&Arc<IntNet>, &str) = if round % 2 == 0 {
            (&net_b, "b")
        } else {
            (&net_a, "a")
        };
        let v = registry.publish(Arc::clone(net), label).unwrap();
        published.push((v, Arc::clone(net)));
        for _ in 0..10 {
            let x: Vec<f32> =
                (0..DIMS[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let (version, logits) = handle.infer_versioned(x.clone()).unwrap();
            let (_, vnet) = published
                .iter()
                .find(|(pv, _)| *pv == version)
                .expect("response tagged with an unpublished version");
            assert!(
                same(&logits, &vnet.forward(&x, 1)),
                "round {round}: logits disagree with the tagged version"
            );
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 60);
    assert!(stats.swaps >= 1);
}
