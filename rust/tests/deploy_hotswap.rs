//! Hot-swap correctness under concurrent traffic.
//!
//! The registry/serving contract this suite pins:
//!
//! * a `ModelRegistry::publish` during sustained concurrent
//!   `ServerHandle::infer` traffic **never drops or rejects** a
//!   request;
//! * every response is **exactly** one model version's answer — bit
//!   for bit, with a version tag that matches the logits (no torn
//!   batches, no half-swapped model, no mixing);
//! * after the swap drains, responses come from the new version only;
//! * rollback restores the old version for subsequent requests.
//!
//! Pure rust, synthetic fixtures — runs without AOT artifacts.

use std::sync::Arc;
use std::time::Duration;

use bitprune::deploy::{ModelRegistry, RegistryError};
use bitprune::infer::IntNet;
use bitprune::quant::Codebook;
use bitprune::serve::{
    synthetic_net, synthetic_net_cbk, CanaryConfig, CanaryOutcome, ServeConfig, Server,
};
use bitprune::util::rng::Rng;

const DIMS: &[usize] = &[10, 22, 4];

fn fixture(seed: u64) -> Arc<IntNet> {
    Arc::new(synthetic_net(DIMS, seed, 4, 5))
}

/// Bitwise row equality.
fn same(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn swap_under_concurrent_traffic_never_drops_or_mixes() {
    let net_a = fixture(0xA);
    let net_b = fixture(0xB);

    // Fixed per-client sample sets, with solo-forward expectations
    // under both versions computed up front.
    let clients = 4usize;
    let per_client = 60usize;
    let mut rng = Rng::new(0x5AB);
    let samples: Vec<Vec<Vec<f32>>> = (0..clients)
        .map(|_| {
            (0..per_client)
                .map(|_| (0..DIMS[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect())
                .collect()
        })
        .collect();
    // The two versions must actually disagree somewhere, or "matches
    // exactly one version" would be vacuous.
    let probe = &samples[0][0];
    assert!(
        !same(&net_a.forward(probe, 1), &net_b.forward(probe, 1)),
        "fixture nets must produce different logits"
    );

    let registry = Arc::new(ModelRegistry::new(Arc::clone(&net_a), "a").unwrap());
    let server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig {
            threads: 2,
            max_batch: 8,
            batch_window: Duration::from_micros(300),
            max_queue: 4096,
            ..ServeConfig::default()
        },
    )
    .unwrap();

    let total = clients * per_client;
    // Deterministic mid-traffic swap: every client rendezvous at the
    // one-third mark, the swapper publishes while they hold, a second
    // rendezvous releases them — so both versions are guaranteed to
    // serve real traffic regardless of scheduling, with no flaky
    // served-count race.
    let gate_at = per_client / 3;
    let before_swap = std::sync::Barrier::new(clients + 1);
    let after_swap = std::sync::Barrier::new(clients + 1);
    // (client, sample index, version tag, logits) for every response.
    let mut responses: Vec<(usize, usize, u64, Vec<f32>)> = Vec::with_capacity(total);
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for (c, my_samples) in samples.iter().enumerate() {
            let handle = server.handle();
            let (before_swap, after_swap) = (&before_swap, &after_swap);
            joins.push(scope.spawn(move || {
                let mut out = Vec::with_capacity(my_samples.len());
                for (i, x) in my_samples.iter().enumerate() {
                    if i == gate_at {
                        before_swap.wait();
                        after_swap.wait();
                    }
                    // Any Err here is a dropped/rejected request — the
                    // thing the swap must never cause.
                    let (version, logits) = handle
                        .infer_versioned(x.clone())
                        .expect("request rejected during hot-swap");
                    out.push((c, i, version, logits));
                }
                out
            }));
        }
        before_swap.wait();
        registry.publish(Arc::clone(&net_b), "b").unwrap();
        after_swap.wait();
        for j in joins {
            responses.extend(j.join().expect("client thread panicked"));
        }
    });
    assert_eq!(responses.len(), total, "every request must be answered");

    // Every response matches exactly one version's solo forward, and
    // its version tag agrees with which one.
    let mut v1 = 0usize;
    let mut v2 = 0usize;
    for (c, i, version, logits) in &responses {
        let x = &samples[*c][*i];
        let want_a = net_a.forward(x, 1);
        let want_b = net_b.forward(x, 1);
        let is_a = same(logits, &want_a);
        let is_b = same(logits, &want_b);
        match version {
            1 => {
                assert!(
                    is_a,
                    "client {c} sample {i}: tagged v1 but logits are not net A's"
                );
                v1 += 1;
            }
            2 => {
                assert!(
                    is_b,
                    "client {c} sample {i}: tagged v2 but logits are not net B's"
                );
                v2 += 1;
            }
            v => panic!("client {c} sample {i}: impossible version {v}"),
        }
        assert!(
            is_a || is_b,
            "client {c} sample {i}: logits match neither version"
        );
    }
    assert_eq!(v1 + v2, total);
    // The barrier makes the split exact: everything before the gate is
    // v1, everything after is v2.
    assert_eq!(v1, clients * gate_at, "pre-swap responses must all be v1");
    assert_eq!(v2, total - clients * gate_at, "post-swap responses must all be v2");

    // Post-drain: fresh requests are served by the new version only.
    let handle = server.handle();
    for x in samples[0].iter().take(5) {
        let (version, logits) = handle.infer_versioned(x.clone()).unwrap();
        assert_eq!(version, 2, "post-drain response served by the old version");
        assert!(same(&logits, &net_b.forward(x, 1)));
    }

    // Rollback: subsequent requests revert to version 1 / net A.
    registry.rollback(1).unwrap();
    let x = &samples[1][0];
    let (version, logits) = handle.infer_versioned(x.clone()).unwrap();
    assert_eq!(version, 1);
    assert!(same(&logits, &net_a.forward(x, 1)));

    let stats = server.shutdown();
    assert_eq!(stats.requests as usize, total + 5 + 1);
    assert!(stats.swaps >= 2, "publish + rollback both crossed the batcher");
}

#[test]
fn repeated_swaps_stay_consistent() {
    // A/B/A/B… every few batches: the version tag must always agree
    // with the logits, across many transitions.
    let net_a = fixture(0x11);
    let net_b = fixture(0x22);
    let registry = Arc::new(ModelRegistry::new(Arc::clone(&net_a), "a").unwrap());
    let server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig {
            threads: 1,
            max_batch: 4,
            batch_window: Duration::from_micros(200),
            max_queue: 1024,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let handle = server.handle();
    let mut rng = Rng::new(0xAB);
    let mut published = vec![(1u64, Arc::clone(&net_a))];
    for round in 0..6 {
        let (net, label): (&Arc<IntNet>, &str) = if round % 2 == 0 {
            (&net_b, "b")
        } else {
            (&net_a, "a")
        };
        let v = registry.publish(Arc::clone(net), label).unwrap();
        published.push((v, Arc::clone(net)));
        for _ in 0..10 {
            let x: Vec<f32> =
                (0..DIMS[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let (version, logits) = handle.infer_versioned(x.clone()).unwrap();
            let (_, vnet) = published
                .iter()
                .find(|(pv, _)| *pv == version)
                .expect("response tagged with an unpublished version");
            assert!(
                same(&logits, &vnet.forward(&x, 1)),
                "round {round}: logits disagree with the tagged version"
            );
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 60);
    assert!(stats.swaps >= 1);
}

#[test]
fn swap_from_multiply_to_shift_add_codebook_net() {
    // Hot-swap a uniform (multiply-GEMM) incumbent for a PoT
    // (shift-add GEMM) replacement rebuilt from its frozen artifact:
    // every response must still match exactly one version's solo
    // forward, across the kernel change.
    let net_a = fixture(0xA);
    let cbk_src = synthetic_net_cbk(DIMS, 0xCB, 4, 5, Codebook::PowerOfTwo);
    let art = bitprune::deploy::freeze(&cbk_src, "pot");
    let net_b: Arc<IntNet> = Arc::new(
        bitprune::deploy::Artifact::from_bytes(&art.to_bytes())
            .unwrap()
            .instantiate()
            .unwrap(),
    );
    assert!(net_b.layers.iter().all(|l| l.codebook() == Codebook::PowerOfTwo));

    let registry = Arc::new(ModelRegistry::new(Arc::clone(&net_a), "a").unwrap());
    let server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig {
            threads: 2,
            max_batch: 8,
            batch_window: Duration::from_micros(200),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let handle = server.handle();
    let mut rng = Rng::new(0x5CB);
    let mut swapped = false;
    for i in 0..60 {
        if i == 30 {
            registry.publish(Arc::clone(&net_b), "pot").unwrap();
            swapped = true;
        }
        let x: Vec<f32> = (0..DIMS[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let (version, logits) = handle.infer_versioned(x.clone()).unwrap();
        let want = match version {
            1 => net_a.forward(&x, 1),
            2 => net_b.forward(&x, 1),
            v => panic!("impossible version {v}"),
        };
        assert!(
            same(&logits, &want),
            "request {i}: logits disagree with tagged version {version}"
        );
        if swapped && i > 40 {
            assert_eq!(version, 2, "post-drain traffic must run on the codebook net");
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 60);
    assert!(stats.swaps >= 1);
}

#[test]
fn codebook_twin_canary_promotes_on_live_traffic() {
    // A codebook net canaried against itself: the shift-add kernel is
    // bit-identical to the multiply reference, so the twin agrees 100%
    // and must promote — the canary loop holds on the new GEMM.
    let net = Arc::new(synthetic_net_cbk(DIMS, 0x7CB, 4, 5, Codebook::AdditivePot2));
    let registry = Arc::new(ModelRegistry::new(Arc::clone(&net), "apot").unwrap());
    let server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig {
            threads: 1,
            max_batch: 8,
            batch_window: Duration::from_micros(200),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let cv = server
        .start_canary(
            Arc::clone(&net),
            "twin",
            CanaryConfig {
                pct: 50,
                window: 8,
                promote_after: 2,
                min_agreement: 0.95,
                max_latency_ratio: 1000.0,
            },
        )
        .unwrap();
    let handle = server.handle();
    let mut rng = Rng::new(0x9CB);
    let mut promoted = false;
    for _ in 0..400 {
        let x: Vec<f32> = (0..DIMS[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let (_, logits) = handle.infer_versioned(x.clone()).unwrap();
        assert!(same(&logits, &net.forward(&x, 1)), "twin must answer identically");
        if registry.active_version() == cv {
            promoted = true;
            break;
        }
    }
    assert!(promoted, "codebook canary never promoted: {:?}", server.canary_status());
    let status = server.canary_status().unwrap();
    assert_eq!(status.outcome, Some(CanaryOutcome::Promoted { version: cv }));
    server.shutdown();
}

#[test]
fn rollback_past_retention_is_a_typed_error() {
    // Publish past the retention window, then ask for a trimmed
    // version: the error names the version and what *is* retained, and
    // the active version is untouched.
    let registry = ModelRegistry::with_retain(fixture(1), "v1", 2).unwrap();
    for seed in 2u64..=4 {
        registry.publish(fixture(seed), &format!("v{seed}")).unwrap();
    }
    // retain=2 ⇒ only versions 3 and 4 survive.
    match registry.rollback(1) {
        Err(RegistryError::NotRetained { version, retained }) => {
            assert_eq!(version, 1);
            assert_eq!(retained, vec![3, 4]);
        }
        other => panic!("expected NotRetained, got {other:?}"),
    }
    assert_eq!(registry.active_version(), 4);
    // A retained version still rolls back fine afterwards.
    registry.rollback(3).unwrap();
    assert_eq!(registry.active_version(), 3);
}

#[test]
fn canary_blocks_publish_and_rollback_until_resolved() {
    // While an experiment is in flight, version changes that would
    // invalidate it are refused — typed, with the canary version in
    // the error. Ending the canary unblocks them.
    let registry = ModelRegistry::new(fixture(0xA), "a").unwrap();
    registry.publish(fixture(0xB), "b").unwrap();
    let cv = registry.begin_canary(fixture(0xC), "candidate").unwrap();
    assert_eq!(registry.canary_version(), Some(cv));
    assert_eq!(registry.active_version(), 2, "staging must not swap");
    assert_eq!(
        registry.publish(fixture(0xD), "d").unwrap_err(),
        RegistryError::CanaryActive { canary: cv }
    );
    assert_eq!(
        registry.rollback(1).unwrap_err(),
        RegistryError::CanaryActive { canary: cv }
    );
    // Promoting a non-canary version is also refused.
    assert_eq!(
        registry.promote_canary(1).unwrap_err(),
        RegistryError::NotCanary { version: 1, canary: Some(cv) }
    );
    registry.end_canary(cv).unwrap();
    assert_eq!(registry.canary_version(), None);
    assert_eq!(registry.active_version(), 2, "ending leaves the incumbent");
    registry.publish(fixture(0xD), "d").unwrap();
    registry.rollback(2).unwrap();
    assert_eq!(registry.active_version(), 2);
    // With no canary in flight, end/promote are typed no-ops.
    assert_eq!(
        registry.end_canary(cv).unwrap_err(),
        RegistryError::NotCanary { version: cv, canary: None }
    );
}

#[test]
fn drain_refuses_publishes_but_allows_emergency_rollback() {
    let registry = ModelRegistry::new(fixture(0xA), "a").unwrap();
    registry.publish(fixture(0xB), "b").unwrap();
    registry.begin_drain();
    assert!(registry.is_draining());
    assert_eq!(
        registry.publish(fixture(0xC), "c").unwrap_err(),
        RegistryError::Draining
    );
    assert_eq!(
        registry.begin_canary(fixture(0xC), "c").unwrap_err(),
        RegistryError::Draining
    );
    // Serving continues, and rollback — the emergency path — still
    // works during drain.
    assert_eq!(registry.current().version, 2);
    registry.rollback(1).unwrap();
    assert_eq!(registry.active_version(), 1);
}

#[test]
fn healthy_canary_promotes_on_live_traffic() {
    // Canary = the incumbent's identical twin: agreement is 100% and
    // latency statistically indistinguishable, so with a generous
    // latency guard the controller must promote after the configured
    // healthy windows — visible to clients as a version swap.
    let net = fixture(0x77);
    let registry = Arc::new(ModelRegistry::new(Arc::clone(&net), "a").unwrap());
    let server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig {
            threads: 1,
            max_batch: 8,
            batch_window: Duration::from_micros(200),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let cv = server
        .start_canary(
            Arc::clone(&net),
            "twin",
            CanaryConfig {
                pct: 50,
                window: 8,
                promote_after: 2,
                min_agreement: 0.95,
                // Identical nets can still jitter on wall-clock; this
                // test pins the promotion logic, not the latency gate.
                max_latency_ratio: 1000.0,
            },
        )
        .unwrap();
    assert_eq!(cv, 2);
    let handle = server.handle();
    let mut rng = Rng::new(0x9);
    let mut promoted_at = None;
    for i in 0..400 {
        let x: Vec<f32> = (0..DIMS[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let (version, logits) = handle.infer_versioned(x.clone()).unwrap();
        assert!(same(&logits, &net.forward(&x, 1)), "twin must answer identically");
        assert!(version == 1 || version == 2, "impossible version {version}");
        if registry.active_version() == cv {
            promoted_at = Some(i);
            break;
        }
    }
    assert!(
        promoted_at.is_some(),
        "canary never promoted: {:?}",
        server.canary_status()
    );
    let status = server.canary_status().unwrap();
    assert_eq!(status.outcome, Some(CanaryOutcome::Promoted { version: cv }));
    assert_eq!(status.agreement(), Some(1.0));
    assert_eq!(registry.canary_version(), None, "promotion clears the canary slot");
    // Post-promotion traffic runs on the promoted version.
    let (version, _) = handle.infer_versioned(vec![0.1; DIMS[0]]).unwrap();
    assert_eq!(version, cv);
    let stats = server.shutdown();
    assert_eq!(stats.promotions, 1);
    assert_eq!(stats.rollbacks, 0);
    assert!(stats.canary_requests > 0);
}

#[test]
fn disagreeing_canary_rolls_back_before_full_promotion() {
    // Canary = a differently-seeded net: argmaxes disagree on a large
    // fraction of random inputs, so the first closed window must roll
    // it back. The incumbent never stops being active.
    let net_a = fixture(0xA11CE);
    let net_b = fixture(0xB0B);
    let registry = Arc::new(ModelRegistry::new(Arc::clone(&net_a), "a").unwrap());
    let server = Server::start_registry(
        Arc::clone(&registry),
        ServeConfig {
            threads: 1,
            max_batch: 8,
            batch_window: Duration::from_micros(200),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let cv = server
        .start_canary(
            Arc::clone(&net_b),
            "bad",
            CanaryConfig {
                pct: 50,
                window: 16,
                promote_after: 3,
                min_agreement: 0.99,
                max_latency_ratio: 1000.0,
            },
        )
        .unwrap();
    let handle = server.handle();
    let mut rng = Rng::new(0x51);
    let mut resolved = false;
    for _ in 0..600 {
        let x: Vec<f32> = (0..DIMS[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        handle.infer_versioned(x).unwrap();
        if let Some(s) = server.canary_status() {
            if s.outcome.is_some() {
                resolved = true;
                break;
            }
        }
    }
    assert!(resolved, "experiment never resolved: {:?}", server.canary_status());
    let status = server.canary_status().unwrap();
    match &status.outcome {
        Some(CanaryOutcome::RolledBack { version, reason }) => {
            assert_eq!(*version, cv);
            assert!(reason.contains("disagreement"), "unexpected reason: {reason}");
        }
        other => panic!("expected rollback, got {other:?}"),
    }
    assert_eq!(registry.active_version(), 1, "incumbent must stay active");
    assert_eq!(registry.canary_version(), None);
    // Post-rollback traffic is 100% incumbent.
    let x = vec![0.2f32; DIMS[0]];
    let (version, logits) = handle.infer_versioned(x.clone()).unwrap();
    assert_eq!(version, 1);
    assert!(same(&logits, &net_a.forward(&x, 1)));
    let stats = server.shutdown();
    assert_eq!(stats.rollbacks, 1);
    assert_eq!(stats.promotions, 0);
}
