#!/usr/bin/env python3
"""Summarize a `bitprune serve --trace-out` JSONL lifecycle trace.

Each trace line is one event object with at least `event` (type tag)
and `t_us` (monotonic microseconds since the server started):

    admit    {id, queued}
    shed     {reason: "queue_full"|"expired", ...}
    batch    {size, served, version, canary_served}
    swap     {from, to}
    promote  {version}
    rollback {version, reason}

Usage: scripts/trace_summarize.py TRACE.jsonl

Prints per-event counts, batch-size statistics, the served-version
timeline, and the canary verdict if one resolved.  Exits non-zero on a
malformed line (a trace that cannot be parsed is a bug, not noise) or
on an empty trace.
"""

import json
import sys


def die(msg):
    print(f"trace_summarize: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    if len(sys.argv) != 2:
        die("usage: trace_summarize.py TRACE.jsonl")
    path = sys.argv[1]
    counts = {}
    batch_sizes = []
    served_total = 0
    canary_served = 0
    versions = []  # (first_t_us, version) in arrival order
    sheds = {}
    outcome = None
    last_t = -1.0
    n = 0
    try:
        fh = open(path, encoding="utf-8")
    except OSError as e:
        die(f"cannot open {path}: {e}")
    with fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                die(f"{path}:{lineno}: malformed JSON ({e})")
            if not isinstance(ev, dict) or "event" not in ev or "t_us" not in ev:
                die(f"{path}:{lineno}: missing 'event'/'t_us' fields")
            n += 1
            kind = ev["event"]
            counts[kind] = counts.get(kind, 0) + 1
            t = float(ev["t_us"])
            if t < last_t:
                die(f"{path}:{lineno}: non-monotonic t_us ({t} after {last_t})")
            last_t = t
            if kind == "batch":
                batch_sizes.append(int(ev["size"]))
                served_total += int(ev.get("served", 0))
                canary_served += int(ev.get("canary_served", 0))
                v = ev.get("version")
                if v is not None and (not versions or versions[-1][1] != v):
                    versions.append((t, v))
            elif kind == "shed":
                reason = ev.get("reason", "?")
                sheds[reason] = sheds.get(reason, 0) + 1
            elif kind == "promote":
                outcome = f"canary v{ev.get('version')} PROMOTED"
            elif kind == "rollback":
                outcome = (
                    f"canary v{ev.get('version')} ROLLED BACK"
                    f" ({ev.get('reason', 'unspecified')})"
                )
    if n == 0:
        die(f"{path}: empty trace")

    span_s = last_t / 1e6
    print(f"trace: {path}")
    print(f"  {n} events over {span_s:.3f}s")
    for kind in sorted(counts):
        print(f"  {kind:<10} {counts[kind]}")
    if batch_sizes:
        batch_sizes.sort()
        mean = sum(batch_sizes) / len(batch_sizes)
        p95 = batch_sizes[min(len(batch_sizes) - 1, int(0.95 * len(batch_sizes)))]
        print(
            f"  batches: {len(batch_sizes)} | size mean {mean:.2f}"
            f" min {batch_sizes[0]} p95 {p95} max {batch_sizes[-1]}"
        )
        print(f"  served: {served_total} rows ({canary_served} by canary)")
        if span_s > 0:
            print(f"  throughput: {served_total / span_s:.0f} req/s over the trace")
    if versions:
        timeline = " -> ".join(
            f"v{int(v)}@{t / 1e6:.3f}s" for t, v in versions
        )
        print(f"  version timeline: {timeline}")
    if outcome:
        print(f"  outcome: {outcome}")


if __name__ == "__main__":
    main()
