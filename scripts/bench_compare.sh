#!/usr/bin/env bash
# Perf-regression gate over the committed bench trajectory.
#
#   scripts/bench_compare.sh compare BASELINE.json CURRENT.json
#       Compare one suite's fresh run against its committed baseline:
#       for every tracked key, fail when the current median is more
#       than $BENCH_MAX_SLOWDOWN (default 0.30 = 30%) slower than the
#       baseline median.  Tracked keys missing from the current run
#       fail too (a silently dropped bench is a regression in
#       coverage); keys missing from the baseline only warn, so new
#       benches can land before their baseline is refreshed.
#
#   scripts/bench_compare.sh arm CURRENT.json [DEST.json]
#       Promote a freshly measured run to the committed baseline for
#       its suite: refuses a file carrying "seed_estimate": true (that
#       is a placeholder, not a measurement), refuses a run missing
#       any tracked key, then strips the seed_estimate/blocker markers
#       and writes DEST (default: the suite's committed BENCH_*.json
#       at the repo root).  After arming, `compare` hard-FAILs on
#       regressions instead of warning.
#
#   scripts/bench_compare.sh self-test
#       Prove the gate trips: for each committed BENCH_*.json, an
#       identity comparison must PASS and a synthetic copy with every
#       tracked median inflated 1.5x (a 50% slowdown) must FAIL.
#       Also proves the arming path: a simulated real run arms
#       cleanly (markers stripped, identity compare passes), while a
#       seed-estimate file and a run with dropped benches are both
#       refused.  Runs without cargo or benches — this is the CI
#       sanity check that the gate itself works.
#
# Baselines live at the repo root (BENCH_infer.json / BENCH_serve.json /
# BENCH_deploy.json — the committed perf trajectory).  `scripts/bench.sh`
# overwrites them with a fresh run, so CI copies the committed files
# aside before benching (see .github/workflows/ci.yml bench-smoke).
#
# Medians are hardware-dependent: refresh the committed baselines
# (run scripts/bench.sh on the CI runner class, then
# `scripts/bench_compare.sh arm` the result and commit it) whenever a
# PR intentionally changes performance.

set -euo pipefail
ROOT="$(cd "$(dirname "$0")/.." && pwd)"
THRESHOLD="${BENCH_MAX_SLOWDOWN:-0.30}"

gate_py() { # <mode> <args...> — one python, one TRACKED table, two modes
    python3 - "$@" <<'PY'
import json
import sys

mode = sys.argv[1]

# Baselines generated without a measured run carry "seed_estimate": true
# (see the committed seed trajectory).  Against such a baseline the
# comparison still runs and reports, but regressions only warn — the
# numbers are placeholders, not measurements.  scripts/bench.sh never
# writes the marker, so arming the first committed real run flips the
# gate to hard-fail.

# The gated hot-path keys per suite.  Keep this list small and stable:
# every key here must exist in quick-mode runs.
TRACKED = {
    "infer-fastpath": [
        "intnet/forward/64x256x256/4b",
        "intnet/conv_forward/16x32x8x8k3/4b",
        "intnet/forward_grouped/64x256x256/ch248",
        "intnet/forward_shift/64x256x256/pot4b",
        "intnet/forward_shift_grouped/64x256x256/apot-ch248",
        "intnet/forward_simd/64x256x256/4b",
        "intnet/forward_simd_grouped/64x256x256/ch248",
        "intnet/forward_shift_simd/64x256x256/pot4b",
        "rust/fake_quant/16384",
        "bitpack/pack/65536/4b",
    ],
    "serve": [
        "serve/forward/mlp/bs64",
        "serve/server/8clients_x32req",
        "serve/server/overload_shed",
        "serve/server/swap_storm",
    ],
    "deploy": [
        "deploy/parse",
        "deploy/instantiate",
        "deploy/artifact_load_file",
    ],
}


def load(path):
    with open(path) as f:
        doc = json.load(f)
    med = {r["name"]: r.get("median_s") for r in doc.get("benches", [])}
    return doc, doc.get("suite", "?"), med


if mode == "compare":
    base_path, cur_path, threshold = sys.argv[2], sys.argv[3], float(sys.argv[4])
    base_doc, suite, base = load(base_path)
    cur_doc, cur_suite, cur = load(cur_path)
    seeded = bool(base_doc.get("seed_estimate"))
    blocker = base_doc.get("blocker")
    if blocker:
        print(f"NOTE: baseline carries a blocker: {blocker}")
    if seeded:
        # Always loud, not just on failure: a seeded baseline means the
        # gate below cannot hard-fail — "green" here is not a perf signal.
        print(
            "NOTE: GATE DISARMED — baseline carries \"seed_estimate\": true "
            "(placeholder numbers, regressions only WARN).\n"
            "      Arm it: run scripts/bench.sh on the pinned runner, then "
            "scripts/bench_compare.sh arm <BENCH_*.json>"
        )
    bdisp, cdisp = base_doc.get("dispatch"), cur_doc.get("dispatch")
    if bdisp and cdisp and bdisp != cdisp:
        print(
            f"NOTE: kernel dispatch differs — baseline '{bdisp}' vs "
            f"current '{cdisp}'; medians are not from the same datapath"
        )
    if suite != cur_suite:
        sys.exit(f"FAIL: comparing suite '{suite}' against '{cur_suite}'")
    tracked = TRACKED.get(suite)
    if tracked is None:
        sys.exit(f"FAIL: unknown suite '{suite}' (no tracked keys)")

    failures, rows = [], []
    for key in tracked:
        b = base.get(key)
        c = cur.get(key)
        if b is None:
            rows.append((key, "-", "-", "SKIP (no baseline yet)"))
            continue
        if c is None:
            rows.append((key, f"{b:.6f}", "-", "FAIL (missing from current run)"))
            failures.append(key)
            continue
        slowdown = c / b - 1.0
        status = "ok" if slowdown <= threshold else "FAIL"
        if status == "FAIL":
            failures.append(key)
        rows.append((key, f"{b:.6f}", f"{c:.6f}", f"{status} ({slowdown:+.1%})"))

    width = max(len(r[0]) for r in rows)
    print(f"suite '{suite}' vs baseline (gate: >{threshold:.0%} median slowdown fails)")
    for key, b, c, status in rows:
        print(f"  {key:<{width}}  base {b:>12}  cur {c:>12}  {status}")

    if failures:
        msg = f"{len(failures)} tracked key(s) regressed: {', '.join(failures)}"
        if seeded:
            print(
                f"WARN (gate disarmed): {msg}\n"
                "baseline is a seed estimate (\"seed_estimate\": true) — refresh it\n"
                "with a real scripts/bench.sh run and scripts/bench_compare.sh arm"
            )
        else:
            sys.exit(f"FAIL: {msg}")
    else:
        print("PASS")

elif mode == "arm":
    cur_path, dest = sys.argv[2], sys.argv[3]
    doc, suite, med = load(cur_path)
    tracked = TRACKED.get(suite)
    if tracked is None:
        sys.exit(f"FAIL: unknown suite '{suite}' (no tracked keys) in {cur_path}")
    if doc.get("seed_estimate"):
        sys.exit(
            f"FAIL: refusing to arm from {cur_path} — it carries "
            '"seed_estimate": true (a placeholder, not a measurement); '
            "run scripts/bench.sh and arm its output instead"
        )
    missing = [k for k in tracked if med.get(k) is None]
    if missing:
        sys.exit(
            f"FAIL: refusing to arm suite '{suite}' — tracked key(s) "
            f"missing from the run: {', '.join(missing)}"
        )
    doc.pop("seed_estimate", None)
    doc.pop("blocker", None)
    with open(dest, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(
        f"armed suite '{suite}': wrote {len(doc.get('benches', []))} bench "
        f"records to {dest} ({len(tracked)} tracked keys verified, "
        "seed_estimate/blocker stripped — the gate now hard-fails on regressions)"
    )

else:
    sys.exit(f"error: unknown gate_py mode '{mode}'")
PY
}

compare() { # <baseline.json> <current.json>
    gate_py compare "$1" "$2" "$THRESHOLD"
}

arm() { # <current.json> [dest.json]
    local cur="$1" dest="${2:-}"
    if [ -z "$dest" ]; then
        local suite
        suite="$(python3 -c 'import json, sys; print(json.load(open(sys.argv[1])).get("suite", "?"))' "$cur")"
        case "$suite" in
            infer-fastpath) dest="$ROOT/BENCH_infer.json" ;;
            serve)          dest="$ROOT/BENCH_serve.json" ;;
            deploy)         dest="$ROOT/BENCH_deploy.json" ;;
            *) echo "error: unknown suite '$suite' in $cur — pass DEST.json explicitly" >&2; exit 1 ;;
        esac
    fi
    gate_py arm "$cur" "$dest"
}

self_test() {
    local tmpdir pass=0
    tmpdir="$(mktemp -d)"
    trap 'rm -rf "$tmpdir"' RETURN
    for base in "$ROOT"/BENCH_infer.json "$ROOT"/BENCH_serve.json "$ROOT"/BENCH_deploy.json; do
        [ -f "$base" ] || { echo "error: missing committed baseline $base" >&2; exit 1; }
        local name
        name="$(basename "$base")"

        # The self-test proves the *armed* gate semantics, so it builds
        # working copies: "fresh" simulates a real scripts/bench.sh run
        # (no markers), "slow" inflates every median 1.5x, "seeded"
        # forces the marker on, "empty" drops every bench record.
        python3 - "$base" "$tmpdir" "$name" <<'PY'
import json
import sys

src, tmpdir, name = sys.argv[1], sys.argv[2], sys.argv[3]
doc = json.load(open(src))
doc.pop("seed_estimate", None)
doc.pop("blocker", None)
json.dump(doc, open(f"{tmpdir}/fresh_{name}", "w"))
slow = dict(doc)
slow["benches"] = [dict(r) for r in doc.get("benches", [])]
for r in slow["benches"]:
    if r.get("median_s") is not None:
        r["median_s"] = r["median_s"] * 1.5
json.dump(slow, open(f"{tmpdir}/slow_{name}", "w"))
seeded = dict(doc)
seeded["seed_estimate"] = True
json.dump(seeded, open(f"{tmpdir}/seeded_{name}", "w"))
empty = dict(doc)
empty["benches"] = []
json.dump(empty, open(f"{tmpdir}/empty_{name}", "w"))
PY
        echo "== self-test ($name): arming a simulated real run must succeed =="
        arm "$tmpdir/fresh_$name" "$tmpdir/armed_$name"
        python3 - "$tmpdir/armed_$name" <<'PY'
import json
import sys

doc = json.load(open(sys.argv[1]))
assert "seed_estimate" not in doc, "arm left the seed_estimate marker in place"
assert "blocker" not in doc, "arm left the blocker marker in place"
PY

        echo "== self-test ($name): identity comparison must pass =="
        compare "$tmpdir/armed_$name" "$tmpdir/armed_$name"

        echo "== self-test ($name): injected 50% slowdown must fail =="
        if compare "$tmpdir/armed_$name" "$tmpdir/slow_$name"; then
            echo "self-test FAILED: the gate accepted a 50% slowdown on $name" >&2
            exit 1
        fi
        echo "(gate tripped as expected)"

        echo "== self-test ($name): arming a seed-estimate file must be refused =="
        if arm "$tmpdir/seeded_$name" "$tmpdir/never_$name"; then
            echo "self-test FAILED: arm accepted a seed-estimate file on $name" >&2
            exit 1
        fi
        echo "(arm refused as expected)"

        echo "== self-test ($name): arming a run with dropped benches must be refused =="
        if arm "$tmpdir/empty_$name" "$tmpdir/never_$name"; then
            echo "self-test FAILED: arm accepted a run missing tracked keys on $name" >&2
            exit 1
        fi
        echo "(arm refused as expected)"
        pass=$((pass + 1))
    done
    echo "self-test PASSED on $pass suites"
}

case "${1:-}" in
    compare)
        [ $# -eq 3 ] || { echo "usage: $0 compare BASELINE.json CURRENT.json" >&2; exit 2; }
        compare "$2" "$3"
        ;;
    arm)
        [ $# -eq 2 ] || [ $# -eq 3 ] || { echo "usage: $0 arm CURRENT.json [DEST.json]" >&2; exit 2; }
        arm "$2" "${3:-}"
        ;;
    self-test)
        self_test
        ;;
    *)
        echo "usage: $0 compare BASELINE.json CURRENT.json | $0 arm CURRENT.json [DEST.json] | $0 self-test" >&2
        exit 2
        ;;
esac
