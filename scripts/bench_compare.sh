#!/usr/bin/env bash
# Perf-regression gate over the committed bench trajectory.
#
#   scripts/bench_compare.sh compare BASELINE.json CURRENT.json
#       Compare one suite's fresh run against its committed baseline:
#       for every tracked key, fail when the current median is more
#       than $BENCH_MAX_SLOWDOWN (default 0.30 = 30%) slower than the
#       baseline median.  Tracked keys missing from the current run
#       fail too (a silently dropped bench is a regression in
#       coverage); keys missing from the baseline only warn, so new
#       benches can land before their baseline is refreshed.
#
#   scripts/bench_compare.sh self-test
#       Prove the gate trips: for each committed BENCH_*.json, an
#       identity comparison must PASS and a synthetic copy with every
#       tracked median inflated 1.5x (a 50% slowdown) must FAIL.
#       Runs without cargo or benches — this is the CI sanity check
#       that the gate itself works.
#
# Baselines live at the repo root (BENCH_infer.json / BENCH_serve.json /
# BENCH_deploy.json — the committed perf trajectory).  `scripts/bench.sh`
# overwrites them with a fresh run, so CI copies the committed files
# aside before benching (see .github/workflows/ci.yml bench-smoke).
#
# Medians are hardware-dependent: refresh the committed baselines
# (run scripts/bench.sh on the CI runner class and commit the result)
# whenever a PR intentionally changes performance.

set -euo pipefail
ROOT="$(cd "$(dirname "$0")/.." && pwd)"
THRESHOLD="${BENCH_MAX_SLOWDOWN:-0.30}"

compare() { # <baseline.json> <current.json>
    python3 - "$1" "$2" "$THRESHOLD" <<'PY'
import json
import sys

base_path, cur_path, threshold = sys.argv[1], sys.argv[2], float(sys.argv[3])

# Baselines generated without a measured run carry "seed_estimate": true
# (see the committed seed trajectory).  Against such a baseline the
# comparison still runs and reports, but regressions only warn — the
# numbers are placeholders, not measurements.  scripts/bench.sh never
# writes the marker, so the first committed real run arms the gate
# automatically.

# The gated hot-path keys per suite.  Keep this list small and stable:
# every key here must exist in quick-mode runs.
TRACKED = {
    "infer-fastpath": [
        "intnet/forward/64x256x256/4b",
        "intnet/conv_forward/16x32x8x8k3/4b",
        "intnet/forward_grouped/64x256x256/ch248",
        "rust/fake_quant/16384",
        "bitpack/pack/65536/4b",
    ],
    "serve": [
        "serve/forward/mlp/bs64",
        "serve/server/8clients_x32req",
        "serve/server/overload_shed",
        "serve/server/swap_storm",
    ],
    "deploy": [
        "deploy/parse",
        "deploy/instantiate",
        "deploy/artifact_load_file",
    ],
}


def medians(path):
    with open(path) as f:
        doc = json.load(f)
    med = {r["name"]: r.get("median_s") for r in doc.get("benches", [])}
    return doc.get("suite", "?"), med, bool(doc.get("seed_estimate")), doc.get("blocker")


suite, base, seeded, blocker = medians(base_path)
cur_suite, cur, _, _ = medians(cur_path)
if blocker:
    print(f"NOTE: baseline carries a blocker: {blocker}")
if suite != cur_suite:
    sys.exit(f"FAIL: comparing suite '{suite}' against '{cur_suite}'")
tracked = TRACKED.get(suite)
if tracked is None:
    sys.exit(f"FAIL: unknown suite '{suite}' (no tracked keys)")

failures, rows = [], []
for key in tracked:
    b = base.get(key)
    c = cur.get(key)
    if b is None:
        rows.append((key, "-", "-", "SKIP (no baseline yet)"))
        continue
    if c is None:
        rows.append((key, f"{b:.6f}", "-", "FAIL (missing from current run)"))
        failures.append(key)
        continue
    slowdown = c / b - 1.0
    status = "ok" if slowdown <= threshold else "FAIL"
    if status == "FAIL":
        failures.append(key)
    rows.append((key, f"{b:.6f}", f"{c:.6f}", f"{status} ({slowdown:+.1%})"))

width = max(len(r[0]) for r in rows)
print(f"suite '{suite}' vs baseline (gate: >{threshold:.0%} median slowdown fails)")
for key, b, c, status in rows:
    print(f"  {key:<{width}}  base {b:>12}  cur {c:>12}  {status}")

if failures:
    msg = f"{len(failures)} tracked key(s) regressed: {', '.join(failures)}"
    if seeded:
        print(
            f"WARN (gate disarmed): {msg}\n"
            "baseline is a seed estimate (\"seed_estimate\": true) — refresh it\n"
            "with a real scripts/bench.sh run to arm the gate"
        )
    else:
        sys.exit(f"FAIL: {msg}")
else:
    print("PASS")
PY
}

self_test() {
    local tmpdir pass=0
    tmpdir="$(mktemp -d)"
    trap 'rm -rf "$tmpdir"' RETURN
    for base in "$ROOT"/BENCH_infer.json "$ROOT"/BENCH_serve.json "$ROOT"/BENCH_deploy.json; do
        [ -f "$base" ] || { echo "error: missing committed baseline $base" >&2; exit 1; }
        local name
        name="$(basename "$base")"

        # The self-test proves the *armed* gate semantics, so it strips
        # any seed_estimate marker from its working copies.
        python3 - "$base" "$tmpdir/armed_$name" "$tmpdir/slow_$name" <<'PY'
import json
import sys

src, armed, slow = sys.argv[1], sys.argv[2], sys.argv[3]
doc = json.load(open(src))
doc.pop("seed_estimate", None)
json.dump(doc, open(armed, "w"))
for r in doc.get("benches", []):
    if r.get("median_s") is not None:
        r["median_s"] = r["median_s"] * 1.5
json.dump(doc, open(slow, "w"))
PY
        echo "== self-test ($name): identity comparison must pass =="
        compare "$tmpdir/armed_$name" "$tmpdir/armed_$name"

        echo "== self-test ($name): injected 50% slowdown must fail =="
        if compare "$tmpdir/armed_$name" "$tmpdir/slow_$name"; then
            echo "self-test FAILED: the gate accepted a 50% slowdown on $name" >&2
            exit 1
        fi
        echo "(gate tripped as expected)"
        pass=$((pass + 1))
    done
    echo "self-test PASSED on $pass suites"
}

case "${1:-}" in
    compare)
        [ $# -eq 3 ] || { echo "usage: $0 compare BASELINE.json CURRENT.json" >&2; exit 2; }
        compare "$2" "$3"
        ;;
    self-test)
        self_test
        ;;
    *)
        echo "usage: $0 compare BASELINE.json CURRENT.json | $0 self-test" >&2
        exit 2
        ;;
esac
