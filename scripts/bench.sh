#!/usr/bin/env bash
# Run the inference fast-path benches and record the perf trajectory at
# the repo root as BENCH_infer.json.
#
# Usage:
#   scripts/bench.sh            # full budgets
#   QUICK=1 scripts/bench.sh    # halved budgets (--quick)
#
# Each bench target appends JSONL records via $BENCH_OUT (see
# util::bench::Bench::flush_jsonl); this script merges them and derives
# fast-vs-ref speedups for every */foo vs */foo_ref pair.

set -euo pipefail
cd "$(dirname "$0")/.."

tmp="$(mktemp)"
trap 'rm -f "$tmp"' EXIT
export BENCH_OUT="$tmp"

quick="${QUICK:+--quick}"

(cd rust && cargo bench --bench quantizer -- $quick)
(cd rust && cargo bench --bench intnet -- $quick)
# end_to_end needs AOT artifacts; it self-skips (and records nothing)
# when they are absent.
(cd rust && cargo bench --bench end_to_end -- $quick)

python3 - "$tmp" BENCH_infer.json <<'PY'
import json
import sys

recs = [json.loads(line) for line in open(sys.argv[1]) if line.strip()]
by_name = {r["name"]: r for r in recs}

speedups = {}
for name, ref in by_name.items():
    # pair "<stage>_ref<suffix>" with "<stage><suffix>"
    if "_ref" not in name:
        continue
    fast = by_name.get(name.replace("_ref", "", 1))
    if fast and ref.get("mean_s") and fast.get("mean_s"):
        speedups[fast["name"]] = round(ref["mean_s"] / fast["mean_s"], 2)

doc = {"suite": "infer-fastpath", "benches": recs, "speedup_vs_ref": speedups}
with open(sys.argv[2], "w") as f:
    json.dump(doc, f, indent=2)
    f.write("\n")
print(f"wrote {sys.argv[2]}: {len(recs)} records, {len(speedups)} speedup pairs")
PY
