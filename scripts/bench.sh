#!/usr/bin/env bash
# Run the perf-tracked bench suites and record the trajectory at the
# repo root:
#   BENCH_infer.json  — inference fast-path suite (quantizer, intnet,
#                       end_to_end)
#   BENCH_serve.json  — serving-engine suite (pooled+buffer-reusing
#                       engine vs per-call forward, server round trip)
#   BENCH_deploy.json — deploy suite (BPMA freeze/serialize/parse/
#                       instantiate/load, swap-under-load latency whose
#                       p99_s is the hot-swap stall number)
#
# Usage:
#   scripts/bench.sh            # full budgets
#   QUICK=1 scripts/bench.sh    # halved budgets (--quick)
#
# Each bench target appends JSONL records via $BENCH_OUT (see
# util::bench); merge_suite derives fast-vs-ref speedups for every
# */foo vs */foo_ref pair.
#
# Output always lands at the repo root (absolute $ROOT paths — the
# script works from any CWD), and a suite that emits no JSONL at all is
# a hard failure instead of a silently empty BENCH_*.json.

set -euo pipefail
ROOT="$(cd "$(dirname "$0")/.." && pwd)"

quick="${QUICK:+--quick}"

merge_suite() { # <suite-name> <jsonl-file> <out-json>
    if [ ! -s "$2" ]; then
        echo "error: suite '$1' emitted no JSONL records — benches failed to run?" >&2
        exit 1
    fi
    python3 - "$1" "$2" "$3" <<'PY'
import json
import sys

suite, src, dst = sys.argv[1:4]
recs = [json.loads(line) for line in open(src) if line.strip()]
if not recs:
    sys.exit(f"error: suite '{suite}' produced an empty record set")

# meta/* records carry run context (kernel dispatch path), not timings:
# lift them out of the bench list into suite-level fields so baselines
# from different runners never silently compare.
meta = [r for r in recs if r["name"].startswith("meta/")]
recs = [r for r in recs if not r["name"].startswith("meta/")]
dispatch = next(
    (m["dispatch"] for m in meta if m["name"] == "meta/kernel_dispatch"), None
)
by_name = {r["name"]: r for r in recs}

speedups = {}
for name, ref in by_name.items():
    # pair "<stage>_ref<suffix>" with "<stage><suffix>"
    if "_ref" not in name:
        continue
    fast = by_name.get(name.replace("_ref", "", 1))
    if fast and ref.get("mean_s") and fast.get("mean_s"):
        speedups[fast["name"]] = round(ref["mean_s"] / fast["mean_s"], 2)

doc = {"suite": suite, "benches": recs, "speedup_vs_ref": speedups}
if dispatch is not None:
    doc["dispatch"] = dispatch
with open(dst, "w") as f:
    json.dump(doc, f, indent=2)
    f.write("\n")
tail = f", dispatch: {dispatch}" if dispatch else ""
print(f"wrote {dst}: {len(recs)} records, {len(speedups)} speedup pairs{tail}")
PY
}

tmp="$(mktemp)"
trap 'rm -f "$tmp"' EXIT

# --- inference fast-path suite -> BENCH_infer.json -------------------
: > "$tmp"
export BENCH_OUT="$tmp"
(cd "$ROOT/rust" && cargo bench --bench quantizer -- $quick)
(cd "$ROOT/rust" && cargo bench --bench intnet -- $quick)
# end_to_end needs AOT artifacts; it self-skips (and records nothing)
# when they are absent.
(cd "$ROOT/rust" && cargo bench --bench end_to_end -- $quick)
merge_suite "infer-fastpath" "$tmp" "$ROOT/BENCH_infer.json"

# --- serving suite -> BENCH_serve.json -------------------------------
: > "$tmp"
(cd "$ROOT/rust" && cargo bench --bench serve -- $quick)
merge_suite "serve" "$tmp" "$ROOT/BENCH_serve.json"

# --- deploy suite -> BENCH_deploy.json -------------------------------
: > "$tmp"
(cd "$ROOT/rust" && cargo bench --bench deploy -- $quick)
merge_suite "deploy" "$tmp" "$ROOT/BENCH_deploy.json"
