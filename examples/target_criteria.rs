//! Weighted bit-loss targeting (paper §III-A5 / Table IV): the same
//! network trained with four λ weightings — equal, batch-1 footprint
//! (weight-heavy), batch-128 footprint (activation-heavy), and MAC
//! count — and the resulting cost metrics compared.
//!
//! The expected shape: each targeted run wins on its own criterion.
//!
//! ```bash
//! make artifacts && cargo run --release --example target_criteria [-- --model alexnet_s]
//! ```

use anyhow::Result;

use bitprune::config::RunConfig;
use bitprune::coordinator::run_experiment;
use bitprune::metrics::Table;
use bitprune::model::ModelMeta;
use bitprune::quant::{self, Criterion};
use bitprune::runtime::Runtime;
use bitprune::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&["model", "steps", "gamma"])?;
    let model = args.get_or("model", "alexnet_s").to_string();
    let steps = args.get_usize("steps", 150)?;

    let base = RunConfig {
        model: model.clone(),
        dataset: "synthcifar".into(),
        gamma: args.get_f64("gamma", 1.0)?,
        learn_steps: steps,
        finetune_steps: steps / 3,
        eval_every: 50,
        ..Default::default()
    };
    let rt = Runtime::cpu(&base.artifact_dir)?;
    let meta = ModelMeta::load(
        rt.artifact_dir().join(format!("{model}_meta.json")),
    )?;

    // Costs normalized to the 8-bit network (lower is better).
    let b8 = vec![8.0f32; meta.num_quant_layers];
    let fp1_8 = quant::total_footprint_bits(&meta, &b8, &b8, 1);
    let fp128_8 = quant::total_footprint_bits(&meta, &b8, &b8, 128);
    let mac_8 = quant::mac_cost(&meta, &b8, &b8);

    let mut t = Table::new(&[
        "target", "accuracy", "BS1 footprint", "BS128 footprint", "bit-MACs",
    ]);
    let mut results = Vec::new();
    for crit in [
        Criterion::Equal,
        Criterion::FootprintBs1,
        Criterion::FootprintBs128,
        Criterion::MacOps,
    ] {
        let mut cfg = base.clone();
        cfg.criterion = crit;
        cfg.name = format!("criteria-{model}-{}", crit.name());
        println!("training with criterion '{}'...", crit.name());
        let out = run_experiment(&rt, &cfg)?;
        let s = &out.final_;
        let fp1 = quant::total_footprint_bits(&meta, &s.bits_w, &s.bits_a, 1) / fp1_8;
        let fp128 =
            quant::total_footprint_bits(&meta, &s.bits_w, &s.bits_a, 128) / fp128_8;
        let mac = quant::mac_cost(&meta, &s.bits_w, &s.bits_a) / mac_8;
        t.row(vec![
            crit.name().into(),
            format!("{:.2}%", s.accuracy * 100.0),
            format!("{:.3}", fp1),
            format!("{:.3}", fp128),
            format!("{:.3}", mac),
        ]);
        results.push((crit, fp1, fp128, mac));
    }
    println!("\n(costs relative to the same network at uniform 8 bits)");
    println!("{}", t.render());

    // Shape check: each targeted criterion should beat the equal run on
    // its own metric.
    let equal = results[0];
    let bs1_wins = results[1].1 <= equal.1;
    let bs128_wins = results[2].2 <= equal.2;
    let mac_wins = results[3].3 <= equal.3;
    println!(
        "targeted-wins: bs1 {} | bs128 {} | mac {}",
        bs1_wins, bs128_wins, mac_wins
    );
    Ok(())
}
