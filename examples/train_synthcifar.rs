//! End-to-end driver (DESIGN.md §7): train the ResNet-style CNN on the
//! SynthCIFAR workload through the full three-layer stack, logging the
//! loss curve, then validate the paper's headline shape:
//!
//!   1. a 16-bit (fp32-proxy) baseline and a BitPruning run train to
//!      comparable accuracy,
//!   2. BitPruning ends below 8 bits on average (aggressive quantization),
//!   3. ceil+fine-tune recovers the integer-selection accuracy drop.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_synthcifar [-- --steps 300]
//! ```

use anyhow::Result;

use bitprune::baselines;
use bitprune::config::RunConfig;
use bitprune::coordinator::run_experiment;
use bitprune::metrics::Table;
use bitprune::model::ModelMeta;
use bitprune::runtime::Runtime;
use bitprune::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&["steps", "finetune", "gamma", "model", "out"])?;
    let learn_steps = args.get_usize("steps", 300)?;
    let finetune_steps = args.get_usize("finetune", 100)?;
    let gamma = args.get_f64("gamma", 1.0)?;
    let model = args.get_or("model", "resnet_s").to_string();

    let base = RunConfig {
        name: format!("e2e-{model}"),
        model: model.clone(),
        dataset: "synthcifar".into(),
        gamma,
        learn_steps,
        finetune_steps,
        eval_every: 25,
        out_dir: args.get_or("out", "reports").to_string(),
        ..Default::default()
    };
    let rt = Runtime::cpu(&base.artifact_dir)?;
    let meta = ModelMeta::load(
        rt.artifact_dir().join(format!("{model}_meta.json")),
    )?;
    println!(
        "end-to-end: {} ({} quant layers, {} params tensors, {:.1}K MACs/sample) on synthcifar",
        model,
        meta.num_quant_layers,
        meta.num_params,
        meta.total_macs_per_sample() as f64 / 1e3,
    );

    // 1. fp32-proxy baseline.
    let bl_cfg = baselines::fp32_proxy_config(&base, &format!("e2e-{model}-baseline"));
    println!("\n[1/2] baseline (16-bit proxy), {} steps...", bl_cfg.learn_steps + bl_cfg.finetune_steps);
    let baseline = run_experiment(&rt, &bl_cfg)?;
    println!(
        "  baseline accuracy: {:.2}%",
        baseline.final_.accuracy * 100.0
    );

    // 2. BitPruning.
    println!("\n[2/2] bitpruning (gamma={gamma}), {} steps...", learn_steps + finetune_steps);
    let bp = run_experiment(&rt, &base)?;
    let names: Vec<String> = meta.layers.iter().map(|l| l.name.clone()).collect();
    bp.recorder.write_csvs(&base.out_dir, &names)?;
    baseline
        .recorder
        .write_csvs(&base.out_dir, &names)?;

    // Loss curve (logged).
    println!("\nloss curve (every 25 steps):");
    for r in bp.recorder.steps.iter().step_by(25) {
        println!(
            "  step {:4} [{}] loss {:.4} (task {:.4} + γ·bits {:.4}) acc {:.2}% bits W {:.2} A {:.2}",
            r.step, r.phase, r.loss, r.task_loss, r.bit_loss,
            r.train_acc * 100.0, r.mean_bits_w, r.mean_bits_a
        );
    }

    let mut t = Table::new(&["run", "stage", "accuracy", "W bits", "A bits"]);
    t.row(vec![
        "baseline".into(), "final".into(),
        format!("{:.2}%", baseline.final_.accuracy * 100.0),
        "16".into(), "16".into(),
    ]);
    if let Some(ni) = &bp.noninteger {
        t.row(vec![
            "bitpruning".into(), "non-integer".into(),
            format!("{:.2}%", ni.accuracy * 100.0),
            format!("{:.2}", ni.mean_bits_w()),
            format!("{:.2}", ni.mean_bits_a()),
        ]);
    }
    t.row(vec![
        "bitpruning".into(), "final (int + finetune)".into(),
        format!("{:.2}%", bp.final_.accuracy * 100.0),
        format!("{:.2}", bp.final_.mean_bits_w()),
        format!("{:.2}", bp.final_.mean_bits_a()),
    ]);
    println!("\n{}", t.render());

    // Headline-shape checks.
    let acc_gap = baseline.final_.accuracy - bp.final_.accuracy;
    let avg_bits =
        (bp.final_.mean_bits_w() + bp.final_.mean_bits_a()) / 2.0;
    println!(
        "accuracy gap vs baseline: {:.2}pp | average bits: {:.2}",
        acc_gap * 100.0,
        avg_bits
    );
    println!(
        "csv: {}/e2e-{}.steps.csv (loss curve), .curve.csv (eval curve), .layers.csv (fig3)",
        base.out_dir, model
    );
    if avg_bits >= 8.0 {
        anyhow::bail!("FAIL: learned bits not below 8 — regularizer ineffective");
    }
    if acc_gap > 0.10 {
        anyhow::bail!(
            "FAIL: accuracy gap {:.1}pp exceeds 10pp — quantization destroyed accuracy",
            acc_gap * 100.0
        );
    }
    println!("END-TO-END OK");
    Ok(())
}
