//! End-to-end conv workload, pure Rust: train on a synthetic
//! CIFAR-like image set, quantize the conv stack to integer ops, and
//! push the model through the whole deployment path.
//!
//!   1. synthesize a 10-class 3×8×8 (HWC) image workload,
//!   2. extract features with a fixed random Conv2d stack (f32
//!      reference forward) and train a softmax head with SGD,
//!   3. build the integer net — `IntConv2d` × 2 + `IntDense` head —
//!      at per-layer or per-output-kernel bitlengths, calibrate, and
//!      compare integer vs f32 accuracy,
//!   4. freeze to a `.bpma` artifact (CNV0 conv-geometry section),
//!      save → load → instantiate, and prove the instantiated net is
//!      bit-exact against the in-memory one.
//!
//! ```bash
//! cargo run --release --example train_synthcifar \
//!     [-- --steps 400 --wbits 6 --abits 7 --granularity channel --out reports]
//! ```

use anyhow::Result;

use bitprune::deploy::artifact::{freeze, Artifact};
use bitprune::infer::{ConvGeom, IntConv2d, IntDense, IntNet};
use bitprune::metrics::Table;
use bitprune::quant;
use bitprune::util::args::Args;
use bitprune::util::rng::Rng;

const CLASSES: usize = 10;
const H: usize = 8;
const W: usize = 8;
const CIN: usize = 3;
const IN_FEATURES: usize = H * W * CIN;

/// Synthetic CIFAR-like set: each class is a fixed random 3×8×8
/// template; a sample is its class template plus i.i.d. noise.  Images
/// are HWC row-major — the layout `IntConv2d` consumes.
fn make_dataset(n: usize, noise: f32, rng: &mut Rng) -> (Vec<f32>, Vec<usize>) {
    let templates: Vec<Vec<f32>> = (0..CLASSES)
        .map(|c| {
            let mut tr = Rng::new(0x5EED_0000 + c as u64);
            (0..IN_FEATURES).map(|_| tr.normal_f32(0.0, 1.0)).collect()
        })
        .collect();
    let mut xs = Vec::with_capacity(n * IN_FEATURES);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below_usize(CLASSES);
        ys.push(c);
        for &t in &templates[c] {
            xs.push(t + rng.normal_f32(0.0, noise));
        }
    }
    (xs, ys)
}

/// f32 reference Conv2d forward: HWC input `[n, h, w, cin]`, flattened
/// HWIO weights `[kh·kw·cin, cout]`, optional ReLU.  Element-at-a-time
/// gather — the float twin of `IntConv2d::forward_ref`.
fn conv2d_f32(x: &[f32], n: usize, w: &[f32], bias: &[f32], g: ConvGeom, relu: bool) -> Vec<f32> {
    let (oh, ow) = (g.out_h(), g.out_w());
    let mut out = vec![0.0f32; n * oh * ow * g.cout];
    for s in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for co in 0..g.cout {
                    let mut acc = bias[co];
                    for ky in 0..g.kh {
                        for kx in 0..g.kw {
                            let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                            let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                            if iy < 0 || ix < 0 || iy as usize >= g.h || ix as usize >= g.w {
                                continue; // zero padding
                            }
                            let (iy, ix) = (iy as usize, ix as usize);
                            for c in 0..g.cin {
                                let xv = x[((s * g.h + iy) * g.w + ix) * g.cin + c];
                                let wv = w[((ky * g.kw + kx) * g.cin + c) * g.cout + co];
                                acc += xv * wv;
                            }
                        }
                    }
                    if relu {
                        acc = acc.max(0.0);
                    }
                    out[((s * oh + oy) * ow + ox) * g.cout + co] = acc;
                }
            }
        }
    }
    out
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

fn accuracy(logits: &[f32], ys: &[usize], k: usize) -> f64 {
    let hits = logits
        .chunks_exact(k)
        .zip(ys)
        .filter(|(row, &y)| argmax(row) == y)
        .count();
    hits as f64 / ys.len() as f64
}

fn main() -> Result<()> {
    let args = Args::from_env(&["steps", "wbits", "abits", "granularity", "out", "seed"])?;
    let steps = args.get_usize("steps", 400)?;
    let wbits = args.get_usize("wbits", 6)? as u32;
    let abits = args.get_usize("abits", 7)? as u32;
    let gran = args.get_or("granularity", "channel").to_string();
    let out_dir = args.get_or("out", "reports").to_string();
    let seed = args.get_usize("seed", 0x51F7)? as u64;
    let per_kernel = match gran.as_str() {
        "channel" => true,
        "layer" => false,
        other => anyhow::bail!("--granularity {other}: expected layer|channel"),
    };

    // Conv stack: 3×8×8 → (k3 s1 p1) 4×8×8 → (k3 s2 p1) 16×4×4 → dense 256→10.
    let g0 = ConvGeom { cin: CIN, h: H, w: W, cout: 4, kh: 3, kw: 3, stride: 1, pad: 1 };
    let g1 = ConvGeom {
        cin: g0.cout, h: g0.out_h(), w: g0.out_w(), cout: 16, kh: 3, kw: 3, stride: 2, pad: 1,
    };
    let dflat = g1.out_features();
    println!(
        "synthcifar-conv: {CLASSES} classes, {CIN}x{H}x{W} HWC -> conv{}/{} -> conv{}/{} -> dense {dflat}->{CLASSES}",
        g0.cout, g0.out_h() * g0.out_w(), g1.cout, g1.out_h() * g1.out_w(),
    );

    // 1. Data.
    let mut rng = Rng::new(seed);
    let (train_x, train_y) = make_dataset(512, 0.8, &mut rng);
    let (test_x, test_y) = make_dataset(256, 0.8, &mut rng);
    let n_train = train_y.len();
    let n_test = test_y.len();

    // 2. Fixed random conv features (He-scaled), f32 reference forward.
    let mut wr = rng.fork(1);
    let he = |fan_in: usize, len: usize, r: &mut Rng| -> Vec<f32> {
        let s = (2.0 / fan_in as f64).sqrt() as f32;
        (0..len).map(|_| r.normal_f32(0.0, s)).collect()
    };
    let w0 = he(g0.patch_len(), g0.patch_len() * g0.cout, &mut wr);
    let b0 = vec![0.0f32; g0.cout];
    let w1 = he(g1.patch_len(), g1.patch_len() * g1.cout, &mut wr);
    let b1 = vec![0.0f32; g1.cout];
    let feat = |x: &[f32], n: usize| -> Vec<f32> {
        let h0 = conv2d_f32(x, n, &w0, &b0, g0, true);
        conv2d_f32(&h0, n, &w1, &b1, g1, true)
    };
    let train_f = feat(&train_x, n_train);
    let test_f = feat(&test_x, n_test);

    // 3. Softmax head, minibatch SGD.
    let mut wh = vec![0.0f32; dflat * CLASSES];
    let mut bh = vec![0.0f32; CLASSES];
    let (batch, lr) = (64usize, 0.05f32);
    let mut order: Vec<usize> = (0..n_train).collect();
    let mut br = rng.fork(2);
    println!("training softmax head: {steps} steps, batch {batch}, lr {lr}");
    for step in 0..steps {
        if step * batch % n_train == 0 {
            br.shuffle(&mut order);
        }
        let idx = &order[(step * batch) % n_train..];
        let idx = &idx[..batch.min(idx.len())];
        let m = idx.len();
        let mut gw = vec![0.0f32; dflat * CLASSES];
        let mut gb = vec![0.0f32; CLASSES];
        let mut loss = 0.0f64;
        for &s in idx {
            let f = &train_f[s * dflat..(s + 1) * dflat];
            let mut z: Vec<f32> = (0..CLASSES)
                .map(|k| bh[k] + f.iter().zip(wh[k..].iter().step_by(CLASSES)).map(|(a, b)| a * b).sum::<f32>())
                .collect();
            let zmax = z.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut zsum = 0.0f32;
            for v in z.iter_mut() {
                *v = (*v - zmax).exp();
                zsum += *v;
            }
            loss += -(f64::from(z[train_y[s]] / zsum)).ln();
            for k in 0..CLASSES {
                let p = z[k] / zsum - if k == train_y[s] { 1.0 } else { 0.0 };
                gb[k] += p;
                for (d, &fv) in f.iter().enumerate() {
                    gw[d * CLASSES + k] += p * fv;
                }
            }
        }
        let scale = lr / m as f32;
        for (w, g) in wh.iter_mut().zip(&gw) {
            *w -= scale * g;
        }
        for (b, g) in bh.iter_mut().zip(&gb) {
            *b -= scale * g;
        }
        if step % 100 == 0 || step + 1 == steps {
            println!("  step {step:4} loss {:.4}", loss / m as f64);
        }
    }

    // f32 accuracy (reference pipeline end to end).
    let head = |f: &[f32], n: usize| -> Vec<f32> {
        let mut z = vec![0.0f32; n * CLASSES];
        for s in 0..n {
            for k in 0..CLASSES {
                z[s * CLASSES + k] = bh[k]
                    + f[s * dflat..(s + 1) * dflat]
                        .iter()
                        .zip(wh[k..].iter().step_by(CLASSES))
                        .map(|(a, b)| a * b)
                        .sum::<f32>();
            }
        }
        z
    };
    let f32_acc = accuracy(&head(&test_f, n_test), &test_y, CLASSES);
    println!("f32 reference accuracy: {:.2}%", f32_acc * 100.0);

    // 4. Integer net at the requested granularity.
    let lb = wbits as f32;
    let mk_conv = |name: &str, w: &[f32], g: ConvGeom, b: &[f32]| -> Result<IntConv2d> {
        if per_kernel {
            let kb = quant::per_channel_bits(w, g.patch_len(), g.cout, lb);
            IntConv2d::new_grouped(name, w, g, b, &kb, abits, true)
        } else {
            IntConv2d::new(name, w, g, b, wbits, abits, true)
        }
    };
    let head_layer = if per_kernel {
        let kb = quant::per_channel_bits(&wh, dflat, CLASSES, lb);
        IntDense::new_grouped("head", &wh, dflat, CLASSES, &bh, &kb, abits, false)?
    } else {
        IntDense::new("head", &wh, dflat, CLASSES, &bh, wbits, abits, false)?
    };
    let mut net = IntNet {
        layers: vec![
            mk_conv("conv0", &w0, g0, &b0)?.into(),
            mk_conv("conv1", &w1, g1, &b1)?.into(),
            head_layer.into(),
        ],
        num_classes: CLASSES,
    };
    net.calibrate(&train_x, n_train)?;
    let int_acc = accuracy(&net.forward(&test_x, n_test), &test_y, CLASSES);

    // MAC + footprint accounting (quant::conv_macs = HLO convention).
    let macs = [
        quant::conv_macs(g0.cin, g0.kh, g0.kw, g0.out_h(), g0.out_w(), g0.cout),
        quant::conv_macs(g1.cin, g1.kh, g1.kw, g1.out_h(), g1.out_w(), g1.cout),
        dflat * CLASSES,
    ];
    let mut t = Table::new(&["layer", "shape", "MACs/sample", "packed B", "f32 B"]);
    for (l, m) in net.layers.iter().zip(macs) {
        t.row(vec![
            l.name().to_string(),
            format!("{}->{}", l.in_features(), l.out_features()),
            m.to_string(),
            l.packed_bytes().to_string(),
            l.f32_bytes().to_string(),
        ]);
    }
    println!("\n{}", t.render());
    println!(
        "granularity {gran}: mean W bits {:.2} | int accuracy {:.2}% (f32 {:.2}%)",
        net.mean_w_bits(),
        int_acc * 100.0,
        f32_acc * 100.0,
    );

    // 5. Freeze -> save -> load -> instantiate, bit-exact.
    let art = freeze(&net, "synthcifar-conv");
    std::fs::create_dir_all(&out_dir)?;
    let path = std::path::Path::new(&out_dir).join("synthcifar_conv.bpma");
    art.save(&path)?;
    let rt = Artifact::load(&path)?.instantiate()?;
    let (a, b) = (net.forward(&test_x, n_test), rt.forward(&test_x, n_test));
    let bit_exact = a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits());
    println!(
        "artifact: {} ({} bytes, conv={}) -> instantiate bit-exact: {bit_exact}",
        path.display(),
        std::fs::metadata(&path)?.len(),
        art.is_conv(),
    );

    // Headline checks.
    if !bit_exact {
        anyhow::bail!("FAIL: instantiated artifact diverges from the in-memory net");
    }
    if f32_acc < 0.5 {
        anyhow::bail!("FAIL: f32 head failed to learn ({:.2}%)", f32_acc * 100.0);
    }
    if int_acc < f32_acc - 0.10 {
        anyhow::bail!(
            "FAIL: integer accuracy {:.2}% more than 10pp below f32 {:.2}%",
            int_acc * 100.0,
            f32_acc * 100.0
        );
    }
    println!("SYNTHCIFAR-CONV OK");
    Ok(())
}
