//! Quickstart: learn bitlengths for a small MLP on the blobs dataset.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the public API end to end: build a RunConfig, run the
//! coordinator (learn → ceil → fine-tune), inspect learned per-layer
//! bitlengths, and estimate the hardware benefit with the accelerator
//! models — all through compiled HLO artifacts; python never runs.

use anyhow::Result;

use bitprune::accel;
use bitprune::config::RunConfig;
use bitprune::coordinator::run_experiment;
use bitprune::metrics::Table;
use bitprune::model::ModelMeta;
use bitprune::runtime::Runtime;

fn main() -> Result<()> {
    let cfg = RunConfig {
        name: "quickstart".into(),
        model: "mlp".into(),
        dataset: "blobs".into(),
        gamma: 1.0,
        learn_steps: 150,
        finetune_steps: 50,
        eval_every: 25,
        ..Default::default()
    };

    let rt = Runtime::cpu(&cfg.artifact_dir)?;
    println!("platform: {}", rt.platform());

    let outcome = run_experiment(&rt, &cfg)?;

    println!("\n== learned bitlengths ==");
    let meta = ModelMeta::load(
        rt.artifact_dir().join(format!("{}_meta.json", cfg.model)),
    )?;
    let mut t = Table::new(&["layer", "weight bits", "activation bits"]);
    for (i, l) in meta.layers.iter().enumerate() {
        t.row(vec![
            l.name.clone(),
            format!("{:.0}", outcome.final_.bits_w[i]),
            format!("{:.0}", outcome.final_.bits_a[i]),
        ]);
    }
    println!("{}", t.render());
    println!(
        "accuracy {:.2}% at avg {:.2}/{:.2} bits (W/A), {:.1}s",
        outcome.final_.accuracy * 100.0,
        outcome.final_.mean_bits_w(),
        outcome.final_.mean_bits_a(),
        outcome.wall_secs,
    );

    println!("\n== estimated accelerator benefit (vs 8-bit) ==");
    let mut t = Table::new(&["accelerator", "speedup", "memory"]);
    for r in accel::evaluate_all(&meta, &outcome.final_.bits_w, &outcome.final_.bits_a) {
        t.row(vec![
            r.accel.into(),
            r.speedup.map_or("-".into(), |s| format!("{s:.2}x")),
            format!("{:.2}x", r.mem_ratio),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
